//! Wire protocol: length-prefixed binary frames, hand-rolled (no serde —
//! the format is small and stable, and the explicit encoding doubles as
//! its own documentation).
//!
//! Frame: `b"BTS" ‖ u8 version ‖ u32 LE payload length ‖ payload`.
//! Payload: `u8 tag ‖ body`. The magic + version prefix fails fast —
//! and with a [`Error::Protocol`] that names the mismatch — when a
//! socket is connected to the wrong service or to a build speaking an
//! older grammar, instead of misparsing a garbage length.
//!
//! The grammar is the transport spine's (DESIGN.md §11): the control
//! plane crosses as [`Down`]/[`Up`] wrapped in [`Message`], and the
//! data plane as `DfsGet`/`DfsPut` → `DfsBlock`/`DfsMiss` — remote
//! workers fetch blocks *through* the leader's replicated store
//! rather than receiving task data inline, so replica selection, the
//! shared block cache, and adaptive replication all still apply to
//! them.

use std::io::{IoSlice, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::assemble::TaskPartial;
use crate::coordinator::{JobOutput, NetflixStats};
use crate::data::Workload;
use crate::error::{Error, Result};
use crate::kneepoint::PackedTask;
use crate::reduce::Partitioner;
use crate::scheduler::TaskSpec;
use crate::transport::{
    DoneItem, Down, ReduceDone, ReduceEnvelope, ReduceSpec, TaskDone,
    TaskEnvelope, Up,
};

/// First bytes of every frame; rejects cross-protocol connections.
pub const MAGIC: [u8; 3] = *b"BTS";

/// Bumped on incompatible grammar changes. Version 1 was the retired
/// inline-data leader/worker protocol; 2 is the transport spine.
pub const PROTOCOL_VERSION: u8 = 2;

/// Refuse frames beyond this size (a corrupt length prefix should fail
/// fast, not allocate gigabytes). Large tasks ship many block keys but
/// the packer keeps multi-sample tasks at kneepoint scale, and DFS
/// blocks are single samples.
pub const MAX_FRAME: u32 = 256 * 1024 * 1024;

/// How long a handshake peer may stay silent before the connection is
/// declared dead ([`Message::read_deadline`] at connect/accept sites).
pub const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);

/// How long a remote worker waits for a `DfsBlock`/`DfsMiss` answer.
pub const DFS_FETCH_TIMEOUT: Duration = Duration::from_secs(30);

/// How long a leader waits for its remote workers to connect.
pub const ACCEPT_TIMEOUT: Duration = Duration::from_secs(120);

/// Remote workers send [`Message::Ping`] at this cadence from a
/// dedicated timer thread, even while the worker body is deep in a
/// long task — the leader-side liveness signal.
pub const PING_INTERVAL: Duration = Duration::from_secs(5);

/// A leader pump that has read nothing for this long (several missed
/// pings) declares the worker silently partitioned and synthesizes
/// `Up::Lost` — a dead peer behind a dropped network cannot wedge the
/// leader even when no FIN/RST ever arrives.
pub const PUMP_IDLE_TIMEOUT: Duration = Duration::from_secs(30);

/// Per-stream read timeout: blocked reads wake at this cadence so
/// idle deadlines can be enforced without losing frame sync (partial
/// progress is preserved by [`read_full`]).
const READ_POLL: Duration = Duration::from_millis(500);

/// Per-stream write timeout: a frame write that cannot complete in
/// this window marks the link dead.
const WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// Socket defaults for every connect/accept site: `TCP_NODELAY` (the
/// control plane is many tiny frames — exactly what Nagle delays),
/// plus read/write timeouts so a hung peer cannot wedge a blocking
/// call forever.
pub fn configure_stream(stream: &TcpStream) -> Result<()> {
    stream.set_nodelay(true)?;
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(READ_POLL))?;
    stream.set_write_timeout(Some(WRITE_TIMEOUT))?;
    Ok(())
}

/// Read exactly `buf.len()` bytes, tolerating read-timeout wakeups.
/// Partial progress is kept across wakeups, so a slow frame never
/// desynchronizes the stream. `idle` bounds the time spent with *no*
/// forward progress (`None` = wait indefinitely; link death still
/// surfaces as EOF/reset).
fn read_full(
    r: &mut impl Read,
    buf: &mut [u8],
    idle: Option<Duration>,
) -> Result<()> {
    let mut got = 0;
    let mut last_progress = Instant::now();
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                return Err(Error::Protocol(
                    "connection closed mid-frame".into(),
                ))
            }
            Ok(n) => {
                got += n;
                last_progress = Instant::now();
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) =>
            {
                if let Some(cap) = idle {
                    if last_progress.elapsed() > cap {
                        return Err(Error::Protocol(format!(
                            "peer silent for {:.0?} (cap {:.0?})",
                            last_progress.elapsed(),
                            cap
                        )));
                    }
                }
            }
            Err(e) => return Err(Error::Io(e)),
        }
    }
    Ok(())
}

const TAG_HELLO: u8 = 1;
const TAG_WELCOME: u8 = 2;
const TAG_TASK: u8 = 3;
const TAG_ABORT: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;
const TAG_DONE: u8 = 6;
const TAG_TASK_FAILED: u8 = 7;
const TAG_ABORTED: u8 = 8;
const TAG_EXITED: u8 = 9;
const TAG_DFS_GET: u8 = 10;
const TAG_DFS_PUT: u8 = 11;
const TAG_DFS_BLOCK: u8 = 12;
const TAG_DFS_MISS: u8 = 13;
const TAG_ERROR: u8 = 14;
const TAG_PING: u8 = 15;
const TAG_REDUCE_TASK: u8 = 16;
const TAG_REDUCE_DONE: u8 = 17;
const TAG_DRAIN: u8 = 18;
const TAG_DRAINED: u8 = 19;
const TAG_DRAIN_REQ: u8 = 20;
const TAG_SUBMIT_JOB: u8 = 21;
const TAG_JOB_ROUTED: u8 = 22;
const TAG_SHED: u8 = 23;
const TAG_LEADER_STATS: u8 = 24;
const TAG_JOB_DONE: u8 = 25;
const TAG_STATS_REQ: u8 = 26;
const TAG_KILL_LEADER: u8 = 27;
const TAG_TASK_BATCH: u8 = 28;
const TAG_DONE_BATCH: u8 = 29;

/// Smallest possible encoded [`TaskEnvelope`] (empty ns, no sample
/// ids); used to guard batch counts against lying frames. Kept
/// conservatively below the true minimum so a future field removal
/// cannot silently turn valid frames into rejects.
const TASK_ENV_MIN_BYTES: usize = 32;

/// Smallest possible encoded [`DoneItem`] (netflix partial with an
/// empty stats vector); same conservative-guard role as
/// [`TASK_ENV_MIN_BYTES`].
const DONE_ITEM_MIN_BYTES: usize = 64;

/// One leader's load digest as carried by [`Message::LeaderStats`]:
/// the front-door's shard map row (DESIGN.md §15).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LeaderStat {
    pub leader: u32,
    /// `false` once the leader has been killed / drained out.
    pub alive: bool,
    /// Jobs currently multiplexed on the leader's pool.
    pub active: u32,
    /// Jobs queued at the front-door for this leader.
    pub queued: u32,
    /// Jobs completed by this leader since the federation started.
    pub completed: u64,
}

/// Everything that crosses a leader↔worker socket. Control messages
/// wrap the transport grammar verbatim; the leader-side pump and the
/// worker-side reader translate between frames and the same channel
/// messages the in-proc transport uses.
#[derive(Debug)]
pub enum Message {
    /// Worker → leader: first frame after connect. `worker` is
    /// advisory (a label for logs); the leader assigns the real slot.
    Hello { worker: u32 },
    /// Leader → worker: slot assignment completing the handshake.
    Welcome { worker: u32 },
    /// Leader → worker control plane.
    Down(Down),
    /// Worker → leader control plane ([`Up::Lost`] is leader-side
    /// synthesized and never crosses the wire; encoding it is a bug).
    Up(Up),
    /// Worker → leader: fetch one block from the replicated store.
    DfsGet { key: String },
    /// Worker → leader: publish one block into the replicated store.
    /// Carries an `Arc` for the same reason as `DfsBlock`: the encode
    /// side writes straight from the shared buffer, and the decode
    /// side hands the single received allocation to `Dfs::put`
    /// without re-owning the bytes.
    DfsPut { key: String, data: Arc<Vec<u8>> },
    /// Leader → worker: `DfsGet` answer. Carries the store's `Arc`
    /// so serving a block to a remote worker never deep-copies it
    /// before the unavoidable frame-buffer write.
    DfsBlock { key: String, data: Arc<Vec<u8>> },
    /// Leader → worker: `DfsGet` failure (missing key, store error).
    DfsMiss { key: String, message: String },
    /// Worker → leader: liveness heartbeat (no body; any frame
    /// counts as progress for the pump's idle clock).
    Ping,
    /// Either direction: fatal protocol-level rejection.
    Error { message: String },
    /// Client → leader (membership plane): ask the leader to drain
    /// slot `worker`. The leader echoes the frame back as the ack.
    DrainWorker { worker: u32 },
    /// Client → front-door: submit one job on behalf of `tenant`.
    /// Carries the full determinism tuple (workload, samples, seed,
    /// reduce shape) so the routed execution is bit-identical to a
    /// direct `bts submit` of the same request.
    SubmitJob {
        tenant: String,
        workload: Workload,
        samples: u64,
        seed: u64,
        deadline_s: Option<f64>,
        reduce_tasks: u32,
        partitioner: Partitioner,
    },
    /// Front-door → client: the job was admitted and routed. `spilled`
    /// marks cross-leader spillover away from the tenant's home shard.
    JobRouted { job: u64, leader: u32, spilled: bool },
    /// Front-door → client: load-shed rejection. The frame header is
    /// versioned like every frame; `retry_after_s` is the backoff
    /// hint (Retry-After semantics), `reason` the structured verdict.
    Shed { retry_after_s: f64, reason: String },
    /// Front-door → client: per-leader load digests (shard map).
    LeaderStats { stats: Vec<LeaderStat> },
    /// Front-door → client: terminal frame carrying the job's output
    /// verbatim (exact f32/f64 bit patterns — the bit-identity oracle
    /// diffs this against direct submission).
    JobDone { job: u64, output: JobOutput },
    /// Client → front-door: ask for the current shard map.
    StatsReq,
    /// Client → front-door (fault injection / ops): kill leader by
    /// index; its tenants re-home to survivors. Answered with the
    /// post-kill [`Message::LeaderStats`].
    KillLeader { leader: u32 },
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    put_u32(out, b.len() as u32);
    out.extend_from_slice(b);
}

fn put_f32s(out: &mut Vec<u8>, vs: &[f32]) {
    put_u32(out, vs.len() as u32);
    for v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_f64s(out: &mut Vec<u8>, vs: &[f64]) {
    put_u32(out, vs.len() as u32);
    for v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

struct Cursor<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.off + n > self.buf.len() {
            return Err(Error::Protocol("truncated frame".into()));
        }
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => {
                Err(Error::Protocol(format!("bad bool byte {other}")))
            }
        }
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.off
    }

    /// Guard a declared element count against the bytes actually left:
    /// every element needs ≥ `elem_bytes`, so a lying count from a
    /// malformed frame fails here instead of sizing a huge allocation.
    fn count(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        if n.saturating_mul(elem_bytes) > self.remaining() {
            return Err(Error::Protocol(format!(
                "count {n} exceeds {} remaining frame bytes",
                self.remaining()
            )));
        }
        Ok(n)
    }

    fn str(&mut self) -> Result<String> {
        let n = self.count(1)?;
        String::from_utf8(self.take(n)?.to_vec()).map_err(|_| {
            Error::Protocol("non-utf8 string in frame".into())
        })
    }

    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.count(1)?;
        Ok(self.take(n)?.to_vec())
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.count(4)?;
        let mut vs = Vec::with_capacity(n);
        for _ in 0..n {
            vs.push(self.f32()?);
        }
        Ok(vs)
    }

    fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.count(8)?;
        let mut vs = Vec::with_capacity(n);
        for _ in 0..n {
            vs.push(self.f64()?);
        }
        Ok(vs)
    }

    fn done(&self) -> Result<()> {
        if self.off != self.buf.len() {
            return Err(Error::Protocol(format!(
                "{} trailing bytes in frame",
                self.buf.len() - self.off
            )));
        }
        Ok(())
    }
}

fn workload_tag(w: Workload) -> u8 {
    match w {
        Workload::Eaglet => 0,
        Workload::NetflixHi => 1,
        Workload::NetflixLo => 2,
        Workload::SeqAddr => 3,
        Workload::Ssag => 4,
    }
}

fn workload_from(tag: u8) -> Result<Workload> {
    match tag {
        0 => Ok(Workload::Eaglet),
        1 => Ok(Workload::NetflixHi),
        2 => Ok(Workload::NetflixLo),
        3 => Ok(Workload::SeqAddr),
        4 => Ok(Workload::Ssag),
        other => Err(Error::Protocol(format!("bad workload tag {other}"))),
    }
}

fn partitioner_tag(p: Partitioner) -> u8 {
    match p {
        Partitioner::Hash => 0,
        Partitioner::Skew => 1,
    }
}

fn partitioner_from(tag: u8) -> Result<Partitioner> {
    match tag {
        0 => Ok(Partitioner::Hash),
        1 => Ok(Partitioner::Skew),
        other => {
            Err(Error::Protocol(format!("bad partitioner tag {other}")))
        }
    }
}

fn encode_partial(out: &mut Vec<u8>, p: &TaskPartial) {
    match p {
        TaskPartial::Eaglet { alod, weight } => {
            out.push(0);
            out.extend_from_slice(&weight.to_le_bytes());
            put_f32s(out, alod);
        }
        TaskPartial::Netflix { stats } => {
            out.push(1);
            put_f32s(out, stats);
        }
    }
}

fn decode_partial(c: &mut Cursor) -> Result<TaskPartial> {
    match c.u8()? {
        0 => {
            let weight = c.f32()?;
            let alod = c.f32s()?;
            Ok(TaskPartial::Eaglet { alod, weight })
        }
        1 => Ok(TaskPartial::Netflix { stats: c.f32s()? }),
        other => {
            Err(Error::Protocol(format!("bad partial tag {other}")))
        }
    }
}

/// [`JobOutput`] crosses the front-door wire with exact `to_le_bytes`
/// bit patterns — the federation bit-identity oracle depends on the
/// decode reconstructing the same floats, not a formatted copy.
fn encode_output(out: &mut Vec<u8>, o: &JobOutput) {
    match o {
        JobOutput::Eaglet { alod, weight } => {
            out.push(0);
            out.extend_from_slice(&weight.to_le_bytes());
            put_f32s(out, alod);
        }
        JobOutput::Netflix(s) => {
            out.push(1);
            put_f64s(out, &s.mean);
            put_f64s(out, &s.ci_half);
            put_f64s(out, &s.count);
        }
    }
}

fn decode_output(c: &mut Cursor) -> Result<JobOutput> {
    match c.u8()? {
        0 => {
            let weight = c.f32()?;
            let alod = c.f32s()?;
            Ok(JobOutput::Eaglet { alod, weight })
        }
        1 => Ok(JobOutput::Netflix(NetflixStats {
            mean: c.f64s()?,
            ci_half: c.f64s()?,
            count: c.f64s()?,
        })),
        other => Err(Error::Protocol(format!("bad output tag {other}"))),
    }
}

/// Body of one [`TaskEnvelope`] — shared by the single-task frame and
/// the batched frame so the two grammars cannot drift.
fn encode_task_env(out: &mut Vec<u8>, t: &TaskEnvelope) {
    put_u64(out, t.job);
    put_u32(out, t.attempt);
    put_str(out, &t.ns);
    out.push(u8::from(t.poison));
    put_u64(out, t.spec.task.seq as u64);
    put_u32(out, t.spec.task.units);
    put_u64(out, t.spec.task.bytes as u64);
    out.push(workload_tag(t.spec.workload));
    put_u64(out, t.spec.seed);
    put_u32(out, t.spec.task.sample_ids.len() as u32);
    for &id in &t.spec.task.sample_ids {
        put_u64(out, id);
    }
}

fn decode_task_env(c: &mut Cursor) -> Result<TaskEnvelope> {
    let job = c.u64()?;
    let attempt = c.u32()?;
    let ns: Arc<str> = c.str()?.into();
    let poison = c.bool()?;
    let seq = c.u64()? as usize;
    let units = c.u32()?;
    let bytes = c.u64()? as usize;
    let workload = workload_from(c.u8()?)?;
    let seed = c.u64()?;
    let n = c.count(8)?;
    let mut sample_ids = Vec::with_capacity(n);
    for _ in 0..n {
        sample_ids.push(c.u64()?);
    }
    Ok(TaskEnvelope {
        job,
        attempt,
        ns,
        spec: TaskSpec {
            task: PackedTask { seq, sample_ids, units, bytes },
            workload,
            seed,
        },
        poison,
    })
}

/// Body of one completed-task ack (job, attempt, [`TaskDone`]) —
/// shared by `TAG_DONE` and `TAG_DONE_BATCH`.
fn encode_done_item(out: &mut Vec<u8>, job: u64, attempt: u32, d: &TaskDone) {
    put_u64(out, job);
    put_u32(out, attempt);
    put_u32(out, d.worker as u32);
    put_u64(out, d.seq as u64);
    encode_partial(out, &d.partial);
    put_f64(out, d.fetch_s);
    put_f64(out, d.exec_s);
    put_f64(out, d.queue_wait_s);
    put_u64(out, d.prefetch_hits);
    put_u64(out, d.prefetch_misses);
    put_u64(out, d.cache_hits);
    put_u64(out, d.cache_misses);
}

fn decode_done_item(c: &mut Cursor) -> Result<DoneItem> {
    let job = c.u64()?;
    let attempt = c.u32()?;
    let worker = c.u32()? as usize;
    let seq = c.u64()? as usize;
    let partial = decode_partial(c)?;
    let done = TaskDone {
        worker,
        seq,
        partial,
        fetch_s: c.f64()?,
        exec_s: c.f64()?,
        queue_wait_s: c.f64()?,
        prefetch_hits: c.u64()?,
        prefetch_misses: c.u64()?,
        cache_hits: c.u64()?,
        cache_misses: c.u64()?,
    };
    Ok(DoneItem { job, attempt, done })
}

impl Message {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }

    /// Encode the payload (tag + body) into `out`, which is cleared
    /// first — the send path reuses one scratch buffer per link
    /// instead of allocating a fresh `Vec` per frame.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.clear();
        match self {
            Message::Hello { worker } => {
                out.push(TAG_HELLO);
                put_u32(out, *worker);
            }
            Message::Welcome { worker } => {
                out.push(TAG_WELCOME);
                put_u32(out, *worker);
            }
            Message::Down(Down::Task(t)) => {
                out.push(TAG_TASK);
                encode_task_env(out, t);
            }
            Message::Down(Down::TaskBatch(ts)) => {
                out.push(TAG_TASK_BATCH);
                put_u32(out, ts.len() as u32);
                for t in ts {
                    encode_task_env(out, t);
                }
            }
            Message::Up(Up::DoneBatch(items)) => {
                out.push(TAG_DONE_BATCH);
                put_u32(out, items.len() as u32);
                for it in items {
                    encode_done_item(out, it.job, it.attempt, &it.done);
                }
            }
            Message::Down(Down::Reduce(r)) => {
                out.push(TAG_REDUCE_TASK);
                put_u64(out, r.job);
                put_u32(out, r.attempt);
                put_str(out, &r.ns);
                put_u32(out, r.spec.partition);
                put_u32(out, r.spec.partitions);
                put_u32(out, r.spec.n_tasks);
                out.push(workload_tag(r.spec.workload));
                put_u32(out, r.spec.keys.len() as u32);
                for &k in &r.spec.keys {
                    put_u32(out, k);
                }
            }
            Message::Down(Down::Abort { job, upto_attempt }) => {
                out.push(TAG_ABORT);
                put_u64(out, *job);
                put_u32(out, *upto_attempt);
            }
            Message::Down(Down::Shutdown) => out.push(TAG_SHUTDOWN),
            Message::Down(Down::Drain) => out.push(TAG_DRAIN),
            Message::Up(Up::Done { job, attempt, done }) => {
                out.push(TAG_DONE);
                encode_done_item(out, *job, *attempt, done);
            }
            Message::Up(Up::ReduceDone { job, attempt, done }) => {
                out.push(TAG_REDUCE_DONE);
                put_u64(out, *job);
                put_u32(out, *attempt);
                put_u32(out, done.worker as u32);
                put_u32(out, done.partition);
                encode_partial(out, &done.partial);
                put_f64(out, done.fetch_s);
                put_f64(out, done.exec_s);
                put_f64(out, done.queue_wait_s);
                put_u64(out, done.shuffle_bytes);
            }
            Message::Up(Up::TaskFailed { job, attempt, worker, error }) => {
                out.push(TAG_TASK_FAILED);
                put_u64(out, *job);
                put_u32(out, *attempt);
                put_u32(out, *worker as u32);
                put_str(out, &error.to_string());
            }
            Message::Up(Up::Aborted { worker, dropped }) => {
                out.push(TAG_ABORTED);
                put_u32(out, *worker as u32);
                put_u64(out, *dropped);
            }
            Message::Up(Up::Exited { worker, executed, clean }) => {
                out.push(TAG_EXITED);
                put_u32(out, *worker as u32);
                put_u64(out, *executed);
                out.push(u8::from(*clean));
            }
            Message::Up(Up::Drained { worker, returned }) => {
                out.push(TAG_DRAINED);
                put_u32(out, *worker as u32);
                put_u64(out, *returned);
            }
            Message::Up(Up::Lost { .. }) => {
                unreachable!("Up::Lost is leader-side only, never framed")
            }
            Message::DfsGet { key } => {
                out.push(TAG_DFS_GET);
                put_str(out, key);
            }
            Message::DfsPut { key, data } => {
                out.push(TAG_DFS_PUT);
                put_str(out, key);
                put_bytes(out, data);
            }
            Message::DfsBlock { key, data } => {
                out.push(TAG_DFS_BLOCK);
                put_str(out, key);
                put_bytes(out, data);
            }
            Message::DfsMiss { key, message } => {
                out.push(TAG_DFS_MISS);
                put_str(out, key);
                put_str(out, message);
            }
            Message::Ping => out.push(TAG_PING),
            Message::Error { message } => {
                out.push(TAG_ERROR);
                put_str(out, message);
            }
            Message::DrainWorker { worker } => {
                out.push(TAG_DRAIN_REQ);
                put_u32(out, *worker);
            }
            Message::SubmitJob {
                tenant,
                workload,
                samples,
                seed,
                deadline_s,
                reduce_tasks,
                partitioner,
            } => {
                out.push(TAG_SUBMIT_JOB);
                put_str(out, tenant);
                out.push(workload_tag(*workload));
                put_u64(out, *samples);
                put_u64(out, *seed);
                match deadline_s {
                    Some(d) => {
                        out.push(1);
                        put_f64(out, *d);
                    }
                    None => out.push(0),
                }
                put_u32(out, *reduce_tasks);
                out.push(partitioner_tag(*partitioner));
            }
            Message::JobRouted { job, leader, spilled } => {
                out.push(TAG_JOB_ROUTED);
                put_u64(out, *job);
                put_u32(out, *leader);
                out.push(u8::from(*spilled));
            }
            Message::Shed { retry_after_s, reason } => {
                out.push(TAG_SHED);
                put_f64(out, *retry_after_s);
                put_str(out, reason);
            }
            Message::LeaderStats { stats } => {
                out.push(TAG_LEADER_STATS);
                put_u32(out, stats.len() as u32);
                for s in stats {
                    put_u32(out, s.leader);
                    out.push(u8::from(s.alive));
                    put_u32(out, s.active);
                    put_u32(out, s.queued);
                    put_u64(out, s.completed);
                }
            }
            Message::JobDone { job, output } => {
                out.push(TAG_JOB_DONE);
                put_u64(out, *job);
                encode_output(out, output);
            }
            Message::StatsReq => out.push(TAG_STATS_REQ),
            Message::KillLeader { leader } => {
                out.push(TAG_KILL_LEADER);
                put_u32(out, *leader);
            }
        }
    }

    pub fn decode(payload: &[u8]) -> Result<Message> {
        let mut c = Cursor { buf: payload, off: 0 };
        let tag = c.u8()?;
        let msg = Self::decode_body(tag, &mut c)?;
        c.done()?;
        Ok(msg)
    }

    /// Decode one payload body given its already-consumed tag — the
    /// shared core of [`Message::decode`] and [`FrameReader::read`]
    /// (which peels the tag off the stream so data-plane payloads can
    /// bypass the scratch buffer).
    fn decode_body(tag: u8, c: &mut Cursor) -> Result<Message> {
        let msg = match tag {
            TAG_HELLO => Message::Hello { worker: c.u32()? },
            TAG_WELCOME => Message::Welcome { worker: c.u32()? },
            TAG_TASK => {
                Message::Down(Down::Task(Box::new(decode_task_env(c)?)))
            }
            TAG_TASK_BATCH => {
                let n = c.count(TASK_ENV_MIN_BYTES)?;
                let mut ts = Vec::with_capacity(n);
                for _ in 0..n {
                    ts.push(decode_task_env(c)?);
                }
                Message::Down(Down::TaskBatch(ts))
            }
            TAG_DONE_BATCH => {
                let n = c.count(DONE_ITEM_MIN_BYTES)?;
                let mut items = Vec::with_capacity(n);
                for _ in 0..n {
                    items.push(decode_done_item(c)?);
                }
                Message::Up(Up::DoneBatch(items))
            }
            TAG_REDUCE_TASK => {
                let job = c.u64()?;
                let attempt = c.u32()?;
                let ns: Arc<str> = c.str()?.into();
                let partition = c.u32()?;
                let partitions = c.u32()?;
                let n_tasks = c.u32()?;
                let workload = workload_from(c.u8()?)?;
                let n = c.count(4)?;
                let mut keys = Vec::with_capacity(n);
                for _ in 0..n {
                    keys.push(c.u32()?);
                }
                Message::Down(Down::Reduce(Box::new(ReduceEnvelope {
                    job,
                    attempt,
                    ns,
                    spec: ReduceSpec {
                        partition,
                        partitions,
                        n_tasks,
                        workload,
                        keys,
                    },
                })))
            }
            TAG_ABORT => Message::Down(Down::Abort {
                job: c.u64()?,
                upto_attempt: c.u32()?,
            }),
            TAG_SHUTDOWN => Message::Down(Down::Shutdown),
            TAG_DRAIN => Message::Down(Down::Drain),
            TAG_DRAINED => Message::Up(Up::Drained {
                worker: c.u32()? as usize,
                returned: c.u64()?,
            }),
            TAG_DRAIN_REQ => Message::DrainWorker { worker: c.u32()? },
            TAG_DONE => {
                let it = decode_done_item(c)?;
                Message::Up(Up::Done {
                    job: it.job,
                    attempt: it.attempt,
                    done: Box::new(it.done),
                })
            }
            TAG_REDUCE_DONE => {
                let job = c.u64()?;
                let attempt = c.u32()?;
                let worker = c.u32()? as usize;
                let partition = c.u32()?;
                let partial = decode_partial(c)?;
                let done = ReduceDone {
                    worker,
                    partition,
                    partial,
                    fetch_s: c.f64()?,
                    exec_s: c.f64()?,
                    queue_wait_s: c.f64()?,
                    shuffle_bytes: c.u64()?,
                };
                Message::Up(Up::ReduceDone {
                    job,
                    attempt,
                    done: Box::new(done),
                })
            }
            TAG_TASK_FAILED => Message::Up(Up::TaskFailed {
                job: c.u64()?,
                attempt: c.u32()?,
                worker: c.u32()? as usize,
                // `Other` renders the message verbatim — the original
                // variant's Display prefix is already baked in.
                error: Error::Other(c.str()?),
            }),
            TAG_ABORTED => Message::Up(Up::Aborted {
                worker: c.u32()? as usize,
                dropped: c.u64()?,
            }),
            TAG_EXITED => Message::Up(Up::Exited {
                worker: c.u32()? as usize,
                executed: c.u64()?,
                clean: c.bool()?,
            }),
            TAG_DFS_GET => Message::DfsGet { key: c.str()? },
            TAG_DFS_PUT => Message::DfsPut {
                key: c.str()?,
                data: Arc::new(c.bytes()?),
            },
            TAG_DFS_BLOCK => Message::DfsBlock {
                key: c.str()?,
                data: Arc::new(c.bytes()?),
            },
            TAG_DFS_MISS => {
                Message::DfsMiss { key: c.str()?, message: c.str()? }
            }
            TAG_PING => Message::Ping,
            TAG_ERROR => Message::Error { message: c.str()? },
            TAG_SUBMIT_JOB => {
                let tenant = c.str()?;
                let workload = workload_from(c.u8()?)?;
                let samples = c.u64()?;
                let seed = c.u64()?;
                let deadline_s =
                    if c.bool()? { Some(c.f64()?) } else { None };
                Message::SubmitJob {
                    tenant,
                    workload,
                    samples,
                    seed,
                    deadline_s,
                    reduce_tasks: c.u32()?,
                    partitioner: partitioner_from(c.u8()?)?,
                }
            }
            TAG_JOB_ROUTED => Message::JobRouted {
                job: c.u64()?,
                leader: c.u32()?,
                spilled: c.bool()?,
            },
            TAG_SHED => Message::Shed {
                retry_after_s: c.f64()?,
                reason: c.str()?,
            },
            TAG_LEADER_STATS => {
                let n = c.count(21)?;
                let mut stats = Vec::with_capacity(n);
                for _ in 0..n {
                    stats.push(LeaderStat {
                        leader: c.u32()?,
                        alive: c.bool()?,
                        active: c.u32()?,
                        queued: c.u32()?,
                        completed: c.u64()?,
                    });
                }
                Message::LeaderStats { stats }
            }
            TAG_JOB_DONE => {
                let job = c.u64()?;
                let output = decode_output(c)?;
                Message::JobDone { job, output }
            }
            TAG_STATS_REQ => Message::StatsReq,
            TAG_KILL_LEADER => Message::KillLeader { leader: c.u32()? },
            other => {
                return Err(Error::Protocol(format!("unknown tag {other}")))
            }
        };
        Ok(msg)
    }

    /// Write one frame (magic, version, length, payload) and flush.
    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        let payload = self.encode();
        let mut header = [0u8; 8];
        header[..3].copy_from_slice(&MAGIC);
        header[3] = PROTOCOL_VERSION;
        header[4..].copy_from_slice(&(payload.len() as u32).to_le_bytes());
        w.write_all(&header)?;
        w.write_all(&payload)?;
        w.flush()?;
        Ok(())
    }

    /// Read one frame, waiting as long as it takes (read-timeout
    /// wakeups are absorbed; link death surfaces as an error).
    pub fn read_from(r: &mut impl Read) -> Result<Message> {
        Self::read_deadline(r, None)
    }

    /// Read one frame, failing if the peer makes no progress for
    /// `idle` (handshakes and response waits use this so a silent
    /// peer cannot hang a connect/accept site forever).
    pub fn read_deadline(
        r: &mut impl Read,
        idle: Option<Duration>,
    ) -> Result<Message> {
        let mut header = [0u8; 8];
        read_full(r, &mut header, idle)?;
        let len = check_header(&header)?;
        let mut payload = vec![0u8; len as usize];
        read_full(r, &mut payload, idle)?;
        Message::decode(&payload)
    }
}

/// Validate a frame header (magic, version, length cap) and return
/// the declared payload length.
fn check_header(header: &[u8; 8]) -> Result<u32> {
    if header[..3] != MAGIC {
        return Err(Error::Protocol(format!(
            "bad frame magic {:?} (not a bts peer?)",
            &header[..3]
        )));
    }
    if header[3] != PROTOCOL_VERSION {
        return Err(Error::Protocol(format!(
            "peer speaks protocol version {}, this build speaks {}",
            header[3], PROTOCOL_VERSION
        )));
    }
    let len = u32::from_le_bytes(header[4..].try_into().unwrap());
    if len > MAX_FRAME {
        return Err(Error::Protocol(format!(
            "frame of {len} bytes exceeds cap"
        )));
    }
    Ok(len)
}

/// Data-plane counters for one endpoint (a leader's link set, or one
/// remote worker process). Shared as an `Arc` and bumped by
/// [`FramedWriter`]; a leader folds the totals into `JobReport` /
/// `ServeReport` after the run. Deliberately *not* a global static:
/// parallel jobs in one process (tests, the serve pool, federation
/// leaders) each get their own instance.
#[derive(Debug, Default)]
pub struct NetCounters {
    /// Frames written (batch frames count once).
    pub frames_sent: AtomicU64,
    /// Control messages that crossed inside a batch frame (sum of
    /// batch lengths) — the dispatch volume that skipped per-message
    /// framing.
    pub frames_batched: AtomicU64,
    /// Total bytes written, headers included.
    pub wire_bytes: AtomicU64,
    /// Data-plane frames (`DfsBlock`/`DfsPut`) whose payload bytes
    /// were emitted straight from the shared `Arc` via vectored
    /// writes, with no copy into a frame buffer.
    pub blocks_zero_copy: AtomicU64,
}

/// One consistent snapshot of [`NetCounters`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NetTotals {
    pub frames_sent: u64,
    pub frames_batched: u64,
    pub wire_bytes: u64,
    pub blocks_zero_copy: u64,
}

impl NetCounters {
    pub fn totals(&self) -> NetTotals {
        NetTotals {
            frames_sent: self.frames_sent.load(Ordering::Relaxed),
            frames_batched: self.frames_batched.load(Ordering::Relaxed),
            wire_bytes: self.wire_bytes.load(Ordering::Relaxed),
            blocks_zero_copy: self.blocks_zero_copy.load(Ordering::Relaxed),
        }
    }
}

/// Write `head` then `body` as one logical frame using vectored I/O,
/// tolerating partial writes. `IoSlice::advance_slices` is not on the
/// MSRV, so the advance is done by re-slicing.
fn write_all_vectored2(
    w: &mut impl Write,
    mut head: &[u8],
    mut body: &[u8],
) -> Result<()> {
    while !head.is_empty() || !body.is_empty() {
        let n = if head.is_empty() {
            w.write(body)?
        } else if body.is_empty() {
            w.write(head)?
        } else {
            w.write_vectored(&[IoSlice::new(head), IoSlice::new(body)])?
        };
        if n == 0 {
            return Err(Error::Protocol(
                "connection closed mid-frame write".into(),
            ));
        }
        let from_head = n.min(head.len());
        head = &head[from_head..];
        body = &body[n - from_head..];
    }
    Ok(())
}

/// Owning frame writer for one socket: reuses a single scratch buffer
/// across sends (no per-frame `Vec`), emits `DfsBlock`/`DfsPut`
/// payload bytes straight from their shared `Arc<Vec<u8>>` via
/// [`Write::write_vectored`], and bumps the endpoint's
/// [`NetCounters`]. Control frames still pay one encode into the
/// scratch buffer — they are tiny; the data plane is where copies
/// cost.
pub struct FramedWriter<W: Write> {
    w: W,
    scratch: Vec<u8>,
    counters: Arc<NetCounters>,
}

impl<W: Write> FramedWriter<W> {
    pub fn new(w: W, counters: Arc<NetCounters>) -> Self {
        FramedWriter { w, scratch: Vec::new(), counters }
    }

    /// Write one frame and flush. Flushing per send keeps reply
    /// latency flat; the caller-side batching (one `TaskBatch` /
    /// `DoneBatch` frame per wakeup) is what collapses flush counts,
    /// not buffering here.
    pub fn send(&mut self, msg: &Message) -> Result<()> {
        match msg {
            Message::DfsBlock { key, data } => {
                self.send_data(TAG_DFS_BLOCK, key, data)
            }
            Message::DfsPut { key, data } => {
                self.send_data(TAG_DFS_PUT, key, data)
            }
            _ => {
                msg.encode_into(&mut self.scratch);
                let mut header = [0u8; 8];
                header[..3].copy_from_slice(&MAGIC);
                header[3] = PROTOCOL_VERSION;
                header[4..].copy_from_slice(
                    &(self.scratch.len() as u32).to_le_bytes(),
                );
                self.w.write_all(&header)?;
                self.w.write_all(&self.scratch)?;
                self.w.flush()?;
                let coalesced = match msg {
                    Message::Down(Down::TaskBatch(ts)) => ts.len() as u64,
                    Message::Up(Up::DoneBatch(items)) => items.len() as u64,
                    _ => 0,
                };
                self.note_sent(8 + self.scratch.len() as u64, coalesced);
                Ok(())
            }
        }
    }

    /// Zero-copy data-plane send: header + tag + key + data length go
    /// into the scratch buffer, the block bytes are emitted from the
    /// `Arc` itself.
    fn send_data(&mut self, tag: u8, key: &str, data: &[u8]) -> Result<()> {
        let payload_len = 1 + 4 + key.len() + 4 + data.len();
        self.scratch.clear();
        self.scratch.extend_from_slice(&MAGIC);
        self.scratch.push(PROTOCOL_VERSION);
        self.scratch
            .extend_from_slice(&(payload_len as u32).to_le_bytes());
        self.scratch.push(tag);
        put_str(&mut self.scratch, key);
        put_u32(&mut self.scratch, data.len() as u32);
        write_all_vectored2(&mut self.w, &self.scratch, data)?;
        self.w.flush()?;
        self.counters.blocks_zero_copy.fetch_add(1, Ordering::Relaxed);
        self.note_sent((self.scratch.len() + data.len()) as u64, 0);
        Ok(())
    }

    fn note_sent(&self, bytes: u64, coalesced: u64) {
        self.counters.frames_sent.fetch_add(1, Ordering::Relaxed);
        self.counters.wire_bytes.fetch_add(bytes, Ordering::Relaxed);
        if coalesced > 0 {
            self.counters
                .frames_batched
                .fetch_add(coalesced, Ordering::Relaxed);
        }
    }
}

/// Owning frame reader for one socket: reuses a single scratch
/// buffer for control payloads, and reads `DfsBlock`/`DfsPut` block
/// bytes *once*, directly into the allocation that becomes the final
/// `Arc<Vec<u8>>` handed to the cache/store — no decode-side copy.
#[derive(Default)]
pub struct FrameReader {
    scratch: Vec<u8>,
}

impl FrameReader {
    pub fn new() -> Self {
        Self::default()
    }

    /// Read one frame with the same idle semantics as
    /// [`Message::read_deadline`].
    pub fn read(
        &mut self,
        r: &mut impl Read,
        idle: Option<Duration>,
    ) -> Result<Message> {
        let mut header = [0u8; 8];
        read_full(r, &mut header, idle)?;
        let len = check_header(&header)? as usize;
        if len == 0 {
            return Err(Error::Protocol("empty frame (no tag)".into()));
        }
        let mut tag = [0u8; 1];
        read_full(r, &mut tag, idle)?;
        let body_len = len - 1;
        match tag[0] {
            t @ (TAG_DFS_BLOCK | TAG_DFS_PUT) => {
                self.read_data_body(r, t, body_len, idle)
            }
            t => {
                self.scratch.resize(body_len, 0);
                read_full(r, &mut self.scratch, idle)?;
                let mut c = Cursor { buf: &self.scratch, off: 0 };
                let msg = Message::decode_body(t, &mut c)?;
                c.done()?;
                Ok(msg)
            }
        }
    }

    /// Decode a data-plane body incrementally off the stream: key via
    /// the scratch buffer, then the block bytes straight into their
    /// final allocation. Lengths are validated against the frame
    /// length before any allocation is sized from them.
    fn read_data_body(
        &mut self,
        r: &mut impl Read,
        tag: u8,
        body_len: usize,
        idle: Option<Duration>,
    ) -> Result<Message> {
        if body_len < 8 {
            return Err(Error::Protocol("truncated frame".into()));
        }
        let mut lenbuf = [0u8; 4];
        read_full(r, &mut lenbuf, idle)?;
        let key_len = u32::from_le_bytes(lenbuf) as usize;
        if key_len + 8 > body_len {
            return Err(Error::Protocol(format!(
                "key of {key_len} bytes exceeds frame"
            )));
        }
        self.scratch.resize(key_len, 0);
        read_full(r, &mut self.scratch, idle)?;
        let key = std::str::from_utf8(&self.scratch)
            .map_err(|_| {
                Error::Protocol("non-utf8 string in frame".into())
            })?
            .to_string();
        read_full(r, &mut lenbuf, idle)?;
        let data_len = u32::from_le_bytes(lenbuf) as usize;
        if data_len != body_len - 8 - key_len {
            return Err(Error::Protocol(format!(
                "data length {data_len} disagrees with frame length"
            )));
        }
        let mut data = vec![0u8; data_len];
        read_full(r, &mut data, idle)?;
        let data = Arc::new(data);
        Ok(if tag == TAG_DFS_BLOCK {
            Message::DfsBlock { key, data }
        } else {
            Message::DfsPut { key, data }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Encode → frame → read back → encode again; byte equality is the
    /// round-trip oracle (several bodies carry types without
    /// `PartialEq`, e.g. `Error`).
    fn round_trip(m: &Message) {
        let mut buf = Vec::new();
        m.write_to(&mut buf).unwrap();
        let back = Message::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(back.encode(), m.encode(), "round trip changed {m:?}");
    }

    fn sample_task(workload: Workload) -> Message {
        Message::Down(Down::Task(Box::new(TaskEnvelope {
            job: 9,
            attempt: 2,
            ns: "j9/".into(),
            spec: TaskSpec {
                task: PackedTask {
                    seq: 4,
                    sample_ids: vec![1, 5, 9],
                    units: 12,
                    bytes: 4096,
                },
                workload,
                seed: 0xDEAD_BEEF,
            },
            poison: true,
        })))
    }

    fn sample_done() -> Message {
        Message::Up(Up::Done {
            job: 3,
            attempt: 1,
            done: Box::new(TaskDone {
                worker: 2,
                seq: 7,
                partial: TaskPartial::Eaglet {
                    alod: vec![0.25, -1.5, 3.0],
                    weight: 4.0,
                },
                fetch_s: 0.002,
                exec_s: 0.015,
                queue_wait_s: 0.0005,
                prefetch_hits: 3,
                prefetch_misses: 1,
                cache_hits: 2,
                cache_misses: 2,
            }),
        })
    }

    fn sample_reduce_task(workload: Workload) -> Message {
        Message::Down(Down::Reduce(Box::new(ReduceEnvelope {
            job: 11,
            attempt: 2,
            ns: "j11/".into(),
            spec: ReduceSpec {
                partition: 1,
                partitions: 4,
                n_tasks: 6,
                workload,
                keys: vec![0, 3, 7, 11],
            },
        })))
    }

    fn sample_reduce_done() -> Message {
        Message::Up(Up::ReduceDone {
            job: 11,
            attempt: 2,
            done: Box::new(ReduceDone {
                worker: 3,
                partition: 1,
                partial: TaskPartial::Eaglet {
                    alod: vec![0.0, 2.5, -0.5],
                    weight: 6.0,
                },
                fetch_s: 0.003,
                exec_s: 0.009,
                queue_wait_s: 0.0007,
                shuffle_bytes: 4096,
            }),
        })
    }

    fn sample_task_batch() -> Message {
        let envs: Vec<TaskEnvelope> = (0..3)
            .map(|i| {
                let Message::Down(Down::Task(t)) =
                    sample_task(Workload::Eaglet)
                else {
                    unreachable!()
                };
                let mut t = *t;
                t.spec.task.seq = i;
                t
            })
            .collect();
        Message::Down(Down::TaskBatch(envs))
    }

    fn sample_done_batch() -> Message {
        let items: Vec<DoneItem> = (0..3)
            .map(|i| {
                let Message::Up(Up::Done { job, attempt, done }) =
                    sample_done()
                else {
                    unreachable!()
                };
                let mut done = *done;
                done.seq = i;
                DoneItem { job, attempt, done }
            })
            .collect();
        Message::Up(Up::DoneBatch(items))
    }

    fn sample_submit() -> Message {
        Message::SubmitJob {
            tenant: "tenant-7".into(),
            workload: Workload::NetflixLo,
            samples: 48,
            seed: 0xB75,
            deadline_s: Some(12.5),
            reduce_tasks: 4,
            partitioner: Partitioner::Skew,
        }
    }

    fn sample_leader_stats() -> Message {
        Message::LeaderStats {
            stats: vec![
                LeaderStat {
                    leader: 0,
                    alive: true,
                    active: 3,
                    queued: 7,
                    completed: 120,
                },
                LeaderStat {
                    leader: 1,
                    alive: false,
                    active: 0,
                    queued: 0,
                    completed: 44,
                },
            ],
        }
    }

    fn sample_job_done_eaglet() -> Message {
        Message::JobDone {
            job: 17,
            output: JobOutput::Eaglet {
                alod: vec![0.5, -2.25, f32::MIN_POSITIVE],
                weight: 9.0,
            },
        }
    }

    fn sample_job_done_netflix() -> Message {
        Message::JobDone {
            job: 18,
            output: JobOutput::Netflix(NetflixStats {
                mean: vec![1.5, 2.5, 3.5],
                ci_half: vec![0.25, 0.125, 0.0625],
                count: vec![10.0, 20.0, 30.0],
            }),
        }
    }

    #[test]
    fn all_messages_round_trip() {
        round_trip(&Message::Hello { worker: 3 });
        round_trip(&Message::Welcome { worker: 7 });
        round_trip(&sample_task(Workload::Eaglet));
        round_trip(&sample_task(Workload::NetflixHi));
        round_trip(&sample_task(Workload::SeqAddr));
        round_trip(&sample_task(Workload::Ssag));
        round_trip(&Message::Down(Down::Abort {
            job: 12,
            upto_attempt: 3,
        }));
        round_trip(&Message::Down(Down::Shutdown));
        round_trip(&sample_reduce_task(Workload::Eaglet));
        round_trip(&sample_reduce_task(Workload::NetflixLo));
        round_trip(&sample_reduce_task(Workload::Ssag));
        round_trip(&sample_reduce_done());
        round_trip(&Message::Up(Up::ReduceDone {
            job: 0,
            attempt: 1,
            done: Box::new(ReduceDone {
                worker: 0,
                partition: 0,
                partial: TaskPartial::Netflix { stats: vec![2.0; 36] },
                fetch_s: 0.0,
                exec_s: 0.0,
                queue_wait_s: 0.0,
                shuffle_bytes: 0,
            }),
        }));
        round_trip(&sample_done());
        round_trip(&Message::Up(Up::Done {
            job: 0,
            attempt: 1,
            done: Box::new(TaskDone {
                worker: 0,
                seq: 0,
                partial: TaskPartial::Netflix { stats: vec![1.0; 9] },
                fetch_s: 0.0,
                exec_s: 0.0,
                queue_wait_s: 0.0,
                prefetch_hits: 0,
                prefetch_misses: 0,
                cache_hits: 0,
                cache_misses: 0,
            }),
        }));
        round_trip(&Message::Up(Up::TaskFailed {
            job: 5,
            attempt: 2,
            worker: 1,
            error: Error::Scheduler("boom: Ω".into()),
        }));
        round_trip(&Message::Up(Up::Aborted { worker: 1, dropped: 4 }));
        round_trip(&Message::Up(Up::Exited {
            worker: 2,
            executed: 40,
            clean: true,
        }));
        round_trip(&Message::DfsGet { key: "j1/eag/7".into() });
        round_trip(&Message::DfsPut {
            key: "j1/eag/8".into(),
            data: Arc::new(vec![1, 2, 3, 4]),
        });
        round_trip(&Message::DfsBlock {
            key: "j1/eag/7".into(),
            data: Arc::new((0..200u8).collect()),
        });
        round_trip(&Message::DfsMiss {
            key: "ghost".into(),
            message: "no replicas".into(),
        });
        round_trip(&Message::Ping);
        round_trip(&Message::Error { message: "go away".into() });
        round_trip(&Message::Down(Down::Drain));
        round_trip(&Message::Up(Up::Drained { worker: 3, returned: 5 }));
        round_trip(&Message::DrainWorker { worker: 2 });
        round_trip(&sample_submit());
        round_trip(&Message::SubmitJob {
            tenant: "t-θ".into(),
            workload: Workload::Eaglet,
            samples: 12,
            seed: 1,
            deadline_s: None,
            reduce_tasks: 1,
            partitioner: Partitioner::Hash,
        });
        round_trip(&Message::JobRouted {
            job: 41,
            leader: 2,
            spilled: true,
        });
        round_trip(&Message::Shed {
            retry_after_s: 2.5,
            reason: "shard 1 backlog beyond cap".into(),
        });
        round_trip(&sample_leader_stats());
        round_trip(&Message::LeaderStats { stats: vec![] });
        round_trip(&sample_job_done_eaglet());
        round_trip(&sample_job_done_netflix());
        round_trip(&Message::StatsReq);
        round_trip(&Message::KillLeader { leader: 1 });
        round_trip(&sample_task_batch());
        round_trip(&sample_done_batch());
        round_trip(&Message::Down(Down::TaskBatch(vec![])));
        round_trip(&Message::Up(Up::DoneBatch(vec![])));
    }

    #[test]
    fn batch_of_one_matches_single_frame_body() {
        // A 1-element batch and the single-message frame share the
        // same body encoder; only tag and count differ. Decoding the
        // batch must reconstruct the identical envelope.
        let m = sample_task(Workload::NetflixHi);
        let Message::Down(Down::Task(t)) = &m else { unreachable!() };
        let batch = Message::Down(Down::TaskBatch(vec![(**t).clone()]));
        let Message::Down(Down::TaskBatch(back)) =
            Message::decode(&batch.encode()).unwrap()
        else {
            panic!("wrong variant")
        };
        assert_eq!(back.len(), 1);
        let single =
            Message::Down(Down::Task(Box::new(back[0].clone()))).encode();
        assert_eq!(single, m.encode());
    }

    #[test]
    fn framed_writer_and_frame_reader_agree_with_the_vec_path() {
        // Every message must produce byte-identical frames through
        // the scratch/vectored writer and decode identically through
        // the incremental reader — the zero-copy path is an encoding
        // of the same grammar, not a second grammar.
        let msgs = vec![
            Message::Hello { worker: 3 },
            sample_task(Workload::Eaglet),
            sample_task_batch(),
            sample_done(),
            sample_done_batch(),
            Message::DfsGet { key: "j1/eag/7".into() },
            Message::DfsPut {
                key: "j1/eag/8".into(),
                data: Arc::new((0..255u8).collect()),
            },
            Message::DfsBlock {
                key: "j1/eag/7".into(),
                data: Arc::new(vec![42; 4096]),
            },
            Message::DfsBlock {
                key: "empty".into(),
                data: Arc::new(vec![]),
            },
            Message::Ping,
        ];
        let counters = Arc::new(NetCounters::default());
        let mut fw = FramedWriter::new(Vec::new(), counters.clone());
        let mut classic = Vec::new();
        for m in &msgs {
            fw.send(m).unwrap();
            m.write_to(&mut classic).unwrap();
        }
        assert_eq!(fw.w, classic, "writer paths diverged");
        let mut rd = FrameReader::new();
        let mut stream = fw.w.as_slice();
        for m in &msgs {
            let back = rd.read(&mut stream, None).unwrap();
            assert_eq!(back.encode(), m.encode(), "reader changed {m:?}");
        }
        assert!(stream.is_empty());
        let t = counters.totals();
        assert_eq!(t.frames_sent, msgs.len() as u64);
        assert_eq!(t.wire_bytes, classic.len() as u64);
        assert_eq!(t.blocks_zero_copy, 3, "DfsPut + 2 DfsBlock");
        assert_eq!(t.frames_batched, 6, "3 tasks + 3 dones coalesced");
    }

    #[test]
    fn decoded_task_preserves_the_exact_seed_and_ids() {
        // The determinism contract hangs on the seed and sample ids
        // crossing untouched (never re-derived on the far side).
        let m = sample_task(Workload::NetflixLo);
        let mut buf = Vec::new();
        m.write_to(&mut buf).unwrap();
        let Message::Down(Down::Task(t)) =
            Message::read_from(&mut buf.as_slice()).unwrap()
        else {
            panic!("wrong variant")
        };
        assert_eq!(t.spec.seed, 0xDEAD_BEEF);
        assert_eq!(t.spec.task.sample_ids, vec![1, 5, 9]);
        assert_eq!(&*t.ns, "j9/");
        assert!(t.poison);
    }

    #[test]
    fn rejects_truncated_and_trailing() {
        let payload = Message::Hello { worker: 1 }.encode();
        assert!(Message::decode(&payload[..payload.len() - 1]).is_err());
        let mut extra = payload.clone();
        extra.push(0);
        assert!(Message::decode(&extra).is_err());
    }

    #[test]
    fn rejects_bad_magic_and_version() {
        let mut buf = Vec::new();
        Message::Hello { worker: 1 }.write_to(&mut buf).unwrap();
        // wrong magic
        let mut bad = buf.clone();
        bad[0] = b'X';
        let err = Message::read_from(&mut bad.as_slice()).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");
        // wrong version
        let mut bad = buf.clone();
        bad[3] = PROTOCOL_VERSION + 1;
        let err = Message::read_from(&mut bad.as_slice()).unwrap_err();
        assert!(
            matches!(err, Error::Protocol(_))
                && err.to_string().contains("version"),
            "{err}"
        );
    }

    #[test]
    fn rejects_bad_tags_and_oversize_frames() {
        assert!(Message::decode(&[99]).is_err());
        let mut buf = Vec::new();
        buf.extend_from_slice(&MAGIC);
        buf.push(PROTOCOL_VERSION);
        buf.extend_from_slice(&(MAX_FRAME + 1).to_le_bytes());
        assert!(Message::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn lying_counts_error_before_allocating() {
        // DfsBlock frame claiming u32::MAX data bytes with a 4-byte
        // body: must be a Protocol error, not a huge allocation.
        let mut payload = vec![TAG_DFS_BLOCK];
        put_str(&mut payload, "k");
        payload.extend_from_slice(&u32::MAX.to_le_bytes());
        payload.extend_from_slice(&[0u8; 4]);
        assert!(Message::decode(&payload).is_err());
        // Task frame with a huge sample-id count.
        let mut payload = vec![TAG_TASK];
        payload.extend_from_slice(&1u64.to_le_bytes()); // job
        payload.extend_from_slice(&1u32.to_le_bytes()); // attempt
        put_str(&mut payload, ""); // ns
        payload.push(0); // poison
        payload.extend_from_slice(&0u64.to_le_bytes()); // seq
        payload.extend_from_slice(&1u32.to_le_bytes()); // units
        payload.extend_from_slice(&64u64.to_le_bytes()); // bytes
        payload.push(0); // workload
        payload.extend_from_slice(&7u64.to_le_bytes()); // seed
        payload.extend_from_slice(&0x00FF_FFFFu32.to_le_bytes());
        assert!(Message::decode(&payload).is_err());
        // Reduce-task frame with a lying key count.
        let mut payload = vec![TAG_REDUCE_TASK];
        payload.extend_from_slice(&1u64.to_le_bytes()); // job
        payload.extend_from_slice(&1u32.to_le_bytes()); // attempt
        put_str(&mut payload, "j1/"); // ns
        payload.extend_from_slice(&0u32.to_le_bytes()); // partition
        payload.extend_from_slice(&4u32.to_le_bytes()); // partitions
        payload.extend_from_slice(&2u32.to_le_bytes()); // n_tasks
        payload.push(0); // workload
        payload.extend_from_slice(&u32::MAX.to_le_bytes()); // key count lie
        assert!(Message::decode(&payload).is_err());
        // ReduceDone frame with a lying partial length.
        let mut payload = vec![TAG_REDUCE_DONE];
        payload.extend_from_slice(&1u64.to_le_bytes()); // job
        payload.extend_from_slice(&1u32.to_le_bytes()); // attempt
        payload.extend_from_slice(&0u32.to_le_bytes()); // worker
        payload.extend_from_slice(&0u32.to_le_bytes()); // partition
        payload.push(0); // eaglet partial
        payload.extend_from_slice(&1.0f32.to_le_bytes()); // weight
        payload.extend_from_slice(&u32::MAX.to_le_bytes()); // count lie
        assert!(Message::decode(&payload).is_err());
        // Done frame with a lying partial length.
        let mut payload = vec![TAG_DONE];
        payload.extend_from_slice(&1u64.to_le_bytes()); // job
        payload.extend_from_slice(&1u32.to_le_bytes()); // attempt
        payload.extend_from_slice(&0u32.to_le_bytes()); // worker
        payload.extend_from_slice(&0u64.to_le_bytes()); // seq
        payload.push(0); // eaglet partial
        payload.extend_from_slice(&1.0f32.to_le_bytes()); // weight
        payload.extend_from_slice(&u32::MAX.to_le_bytes()); // count lie
        assert!(Message::decode(&payload).is_err());
        // TaskBatch frame with a lying envelope count.
        let mut payload = vec![TAG_TASK_BATCH];
        payload.extend_from_slice(&u32::MAX.to_le_bytes()); // count lie
        assert!(Message::decode(&payload).is_err());
        // DoneBatch frame with a lying item count.
        let mut payload = vec![TAG_DONE_BATCH];
        payload.extend_from_slice(&u32::MAX.to_le_bytes()); // count lie
        payload.extend_from_slice(&[0u8; 64]); // one item's worth
        assert!(Message::decode(&payload).is_err());
        // TaskBatch whose inner envelope lies about its id count.
        let mut payload = vec![TAG_TASK_BATCH];
        payload.extend_from_slice(&1u32.to_le_bytes()); // one envelope
        payload.extend_from_slice(&1u64.to_le_bytes()); // job
        payload.extend_from_slice(&1u32.to_le_bytes()); // attempt
        put_str(&mut payload, ""); // ns
        payload.push(0); // poison
        payload.extend_from_slice(&0u64.to_le_bytes()); // seq
        payload.extend_from_slice(&1u32.to_le_bytes()); // units
        payload.extend_from_slice(&64u64.to_le_bytes()); // bytes
        payload.push(0); // workload
        payload.extend_from_slice(&7u64.to_le_bytes()); // seed
        payload.extend_from_slice(&0x00FF_FFFFu32.to_le_bytes()); // lie
        assert!(Message::decode(&payload).is_err());
        // LeaderStats frame with a lying digest count.
        let mut payload = vec![TAG_LEADER_STATS];
        payload.extend_from_slice(&u32::MAX.to_le_bytes()); // count lie
        payload.extend_from_slice(&[0u8; 21]); // one real digest
        assert!(Message::decode(&payload).is_err());
        // JobDone frame with a lying netflix vector length.
        let mut payload = vec![TAG_JOB_DONE];
        payload.extend_from_slice(&1u64.to_le_bytes()); // job
        payload.push(1); // netflix output
        payload.extend_from_slice(&u32::MAX.to_le_bytes()); // mean lie
        assert!(Message::decode(&payload).is_err());
        // SubmitJob frame with a lying tenant length.
        let mut payload = vec![TAG_SUBMIT_JOB];
        payload.extend_from_slice(&u32::MAX.to_le_bytes()); // str lie
        payload.push(b't');
        assert!(Message::decode(&payload).is_err());
    }

    /// Regression: a `TaskBatch` frame (tag 28) cut off at *any* byte
    /// boundary — a peer dying mid-write, or a proxy truncating the
    /// stream — must decode to a clean error at every prefix length,
    /// never a panic and never a silently shorter batch.
    #[test]
    fn truncated_task_batch_frames_error_at_every_prefix() {
        let payload = sample_task_batch().encode();
        assert_eq!(payload[0], TAG_TASK_BATCH);
        assert!(Message::decode(&payload).is_ok(), "full frame decodes");
        for len in 0..payload.len() {
            assert!(
                Message::decode(&payload[..len]).is_err(),
                "prefix of {len}/{} bytes decoded as a valid frame",
                payload.len()
            );
        }
    }

    #[test]
    fn garbage_payloads_never_panic() {
        // Fuzz decode over random byte strings — errors are fine,
        // panics and aborts are not.
        let mut rng = Rng::new(0xFEED);
        for _ in 0..4000 {
            let len = rng.below(96) as usize;
            let bytes: Vec<u8> =
                (0..len).map(|_| rng.below(256) as u8).collect();
            let _ = Message::decode(&bytes);
        }
        // …and over mutated valid frames of every new message kind,
        // the DFS data-plane bodies included.
        let goods: Vec<Vec<u8>> = vec![
            sample_task(Workload::Eaglet).encode(),
            sample_done().encode(),
            sample_reduce_task(Workload::NetflixHi).encode(),
            sample_reduce_done().encode(),
            Message::DfsGet { key: "j2/nfx_hi/41".into() }.encode(),
            Message::DfsPut {
                key: "a".into(),
                data: Arc::new(vec![7; 32]),
            }
            .encode(),
            sample_task_batch().encode(),
            sample_done_batch().encode(),
            Message::DfsBlock {
                key: "j2/nfx_hi/41".into(),
                data: Arc::new(vec![9; 64]),
            }
            .encode(),
            Message::DfsMiss {
                key: "j2/nfx_hi/41".into(),
                message: "gone".into(),
            }
            .encode(),
            Message::Up(Up::Exited {
                worker: 1,
                executed: 9,
                clean: false,
            })
            .encode(),
            Message::Up(Up::Drained { worker: 2, returned: 7 }).encode(),
            Message::DrainWorker { worker: 1 }.encode(),
            sample_submit().encode(),
            Message::JobRouted { job: 3, leader: 0, spilled: false }
                .encode(),
            Message::Shed {
                retry_after_s: 1.0,
                reason: "overloaded".into(),
            }
            .encode(),
            sample_leader_stats().encode(),
            sample_job_done_eaglet().encode(),
            sample_job_done_netflix().encode(),
            Message::KillLeader { leader: 0 }.encode(),
        ];
        for good in goods {
            for _ in 0..2000 {
                let mut bad = good.clone();
                let i = rng.below(bad.len() as u64) as usize;
                bad[i] ^= 1 << rng.below(8);
                let _ = Message::decode(&bad);
            }
        }
    }

    #[test]
    fn frame_reader_rejects_lying_data_plane_lengths() {
        // Key length claiming more bytes than the frame holds: must
        // fail before sizing any allocation from it.
        let mut buf = Vec::new();
        buf.extend_from_slice(&MAGIC);
        buf.push(PROTOCOL_VERSION);
        buf.extend_from_slice(&9u32.to_le_bytes());
        buf.push(TAG_DFS_BLOCK);
        buf.extend_from_slice(&u32::MAX.to_le_bytes()); // key len lie
        buf.extend_from_slice(&0u32.to_le_bytes());
        let err =
            FrameReader::new().read(&mut buf.as_slice(), None).unwrap_err();
        assert!(err.to_string().contains("exceeds frame"), "{err}");
        // Data length disagreeing with the frame length.
        let good = Message::DfsBlock {
            key: "k".into(),
            data: Arc::new(vec![1, 2, 3]),
        };
        let mut buf = Vec::new();
        good.write_to(&mut buf).unwrap();
        // layout: header(8) tag(1) keylen(4) key(1) datalen(4) data(3)
        buf[14] ^= 1;
        let err =
            FrameReader::new().read(&mut buf.as_slice(), None).unwrap_err();
        assert!(err.to_string().contains("disagrees"), "{err}");
    }

    #[test]
    fn truncated_header_is_an_error() {
        // read_from with fewer than 8 header bytes
        let two = [b'B', b'T'];
        assert!(Message::read_from(&mut &two[..]).is_err());
        // declared length longer than the stream
        let mut buf = Vec::new();
        buf.extend_from_slice(&MAGIC);
        buf.push(PROTOCOL_VERSION);
        buf.extend_from_slice(&10u32.to_le_bytes());
        buf.extend_from_slice(&[1, 2, 3]);
        assert!(Message::read_from(&mut &buf[..]).is_err());
    }

    #[test]
    fn workload_tags_round_trip() {
        for w in Workload::ALL {
            assert_eq!(workload_from(workload_tag(w)).unwrap(), w);
        }
        assert!(workload_from(7).is_err());
    }

    #[test]
    fn partitioner_tags_round_trip() {
        for p in [Partitioner::Hash, Partitioner::Skew] {
            assert_eq!(partitioner_from(partitioner_tag(p)).unwrap(), p);
        }
        assert!(partitioner_from(9).is_err());
    }

    #[test]
    fn job_done_preserves_exact_float_bits() {
        // The federation oracle compares decoded outputs with `==`;
        // the wire must carry exact bit patterns, including values
        // that do not survive a decimal print-and-parse cycle.
        let out = JobOutput::Netflix(NetflixStats {
            mean: vec![f64::from_bits(0.1f64.to_bits() + 1), f64::MIN_POSITIVE],
            ci_half: vec![1.0 / 3.0],
            count: vec![7.0],
        });
        let m = Message::JobDone { job: 1, output: out.clone() };
        let mut buf = Vec::new();
        m.write_to(&mut buf).unwrap();
        let Message::JobDone { output: back, .. } =
            Message::read_from(&mut buf.as_slice()).unwrap()
        else {
            panic!("wrong variant")
        };
        assert_eq!(back, out);
    }
}
