//! Wire protocol: length-prefixed binary frames, hand-rolled (no serde —
//! the format is small and stable, and the explicit encoding doubles as
//! its own documentation).
//!
//! Frame: `u32 LE payload length ‖ payload`. Payload: `u8 tag ‖ body`.

use std::io::{Read, Write};

use crate::data::block::Block;
use crate::data::Workload;
use crate::error::{Error, Result};

/// Refuse frames beyond this size (a corrupt length prefix should fail
/// fast, not allocate gigabytes). Large tasks ship many blocks but the
/// packer keeps multi-sample tasks at kneepoint scale.
pub const MAX_FRAME: u32 = 256 * 1024 * 1024;

const TAG_HELLO: u8 = 1;
const TAG_TASK: u8 = 2;
const TAG_PARTIAL: u8 = 3;
const TAG_DONE: u8 = 4;
const TAG_ERROR: u8 = 5;

/// Everything that crosses the leader↔worker socket.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    Hello { worker: u32 },
    /// One map task with its input data inline (the leader "partitions
    /// data and tasks access only the local file system" — here the
    /// local side of that is the frame itself).
    Task {
        seq: u32,
        workload: Workload,
        seed: u64,
        blocks: Vec<Block>,
    },
    /// Eaglet partial: mean ALOD + weight. Netflix partial: stat tensor.
    Partial {
        seq: u32,
        weight: f32,
        values: Vec<f32>,
        netflix: bool,
    },
    Done,
    Error { message: String },
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

struct Cursor<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.off + n > self.buf.len() {
            return Err(Error::Protocol("truncated frame".into()));
        }
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.off
    }

    /// Guard a declared element count against the bytes actually left:
    /// every element needs ≥ `elem_bytes`, so a lying count from a
    /// malformed frame fails here instead of sizing a huge allocation.
    fn count(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        if n.saturating_mul(elem_bytes) > self.remaining() {
            return Err(Error::Protocol(format!(
                "count {n} exceeds {} remaining frame bytes",
                self.remaining()
            )));
        }
        Ok(n)
    }

    fn done(&self) -> Result<()> {
        if self.off != self.buf.len() {
            return Err(Error::Protocol(format!(
                "{} trailing bytes in frame",
                self.buf.len() - self.off
            )));
        }
        Ok(())
    }
}

fn workload_tag(w: Workload) -> u8 {
    match w {
        Workload::Eaglet => 0,
        Workload::NetflixHi => 1,
        Workload::NetflixLo => 2,
    }
}

fn workload_from(tag: u8) -> Result<Workload> {
    match tag {
        0 => Ok(Workload::Eaglet),
        1 => Ok(Workload::NetflixHi),
        2 => Ok(Workload::NetflixLo),
        other => Err(Error::Protocol(format!("bad workload tag {other}"))),
    }
}

impl Message {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            Message::Hello { worker } => {
                out.push(TAG_HELLO);
                put_u32(&mut out, *worker);
            }
            Message::Task { seq, workload, seed, blocks } => {
                out.push(TAG_TASK);
                put_u32(&mut out, *seq);
                out.push(workload_tag(*workload));
                put_u64(&mut out, *seed);
                put_u32(&mut out, blocks.len() as u32);
                for b in blocks {
                    let enc = b.encode();
                    put_u32(&mut out, enc.len() as u32);
                    out.extend_from_slice(&enc);
                }
            }
            Message::Partial { seq, weight, values, netflix } => {
                out.push(TAG_PARTIAL);
                put_u32(&mut out, *seq);
                out.push(u8::from(*netflix));
                out.extend_from_slice(&weight.to_le_bytes());
                put_u32(&mut out, values.len() as u32);
                for v in values {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            Message::Done => out.push(TAG_DONE),
            Message::Error { message } => {
                out.push(TAG_ERROR);
                out.extend_from_slice(message.as_bytes());
            }
        }
        out
    }

    pub fn decode(payload: &[u8]) -> Result<Message> {
        let mut c = Cursor { buf: payload, off: 0 };
        let msg = match c.u8()? {
            TAG_HELLO => Message::Hello { worker: c.u32()? },
            TAG_TASK => {
                let seq = c.u32()?;
                let workload = workload_from(c.u8()?)?;
                let seed = c.u64()?;
                // each block carries at least its u32 length prefix
                let n = c.count(4)?;
                // a decoded Block outweighs its 4-byte wire floor
                // ~12x, so cap the pre-reservation too: a lying count
                // should cost a few pages, not gigabytes, before the
                // first truncated block errors out
                let mut blocks = Vec::with_capacity(n.min(4096));
                for _ in 0..n {
                    let len = c.u32()? as usize;
                    blocks.push(Block::decode(c.take(len)?)?);
                }
                Message::Task { seq, workload, seed, blocks }
            }
            TAG_PARTIAL => {
                let seq = c.u32()?;
                let netflix = c.u8()? != 0;
                let weight = c.f32()?;
                let n = c.count(4)?;
                let mut values = Vec::with_capacity(n);
                for _ in 0..n {
                    values.push(c.f32()?);
                }
                Message::Partial { seq, weight, values, netflix }
            }
            TAG_DONE => Message::Done,
            TAG_ERROR => Message::Error {
                message: String::from_utf8_lossy(
                    c.take(payload.len() - 1)?,
                )
                .into_owned(),
            },
            other => {
                return Err(Error::Protocol(format!("unknown tag {other}")))
            }
        };
        c.done()?;
        Ok(msg)
    }

    /// Write one frame.
    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        let payload = self.encode();
        w.write_all(&(payload.len() as u32).to_le_bytes())?;
        w.write_all(&payload)?;
        w.flush()?;
        Ok(())
    }

    /// Read one frame (blocking).
    pub fn read_from(r: &mut impl Read) -> Result<Message> {
        let mut len = [0u8; 4];
        r.read_exact(&mut len)?;
        let len = u32::from_le_bytes(len);
        if len > MAX_FRAME {
            return Err(Error::Protocol(format!(
                "frame of {len} bytes exceeds cap"
            )));
        }
        let mut payload = vec![0u8; len as usize];
        r.read_exact(&mut payload)?;
        Message::decode(&payload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::block::BlockId;
    use crate::util::rng::Rng;

    fn round_trip(m: &Message) {
        let mut buf = Vec::new();
        m.write_to(&mut buf).unwrap();
        let back = Message::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(&back, m);
    }

    #[test]
    fn all_messages_round_trip() {
        round_trip(&Message::Hello { worker: 3 });
        round_trip(&Message::Done);
        round_trip(&Message::Error { message: "boom: Ω".into() });
        round_trip(&Message::Partial {
            seq: 9,
            weight: 2.5,
            values: vec![1.0, -3.5, 0.0],
            netflix: false,
        });
        let mut rng = Rng::new(1);
        let blocks: Vec<Block> = (0..3)
            .map(|i| Block {
                id: BlockId { kind: 0, sample: i },
                units: 2,
                payload: (0..50).map(|_| rng.f32()).collect(),
            })
            .collect();
        round_trip(&Message::Task {
            seq: 1,
            workload: Workload::Eaglet,
            seed: 0xDEAD,
            blocks,
        });
        round_trip(&Message::Task {
            seq: 2,
            workload: Workload::NetflixHi,
            seed: 1,
            blocks: vec![],
        });
    }

    #[test]
    fn rejects_truncated_and_trailing() {
        let m = Message::Hello { worker: 1 };
        let payload = m.encode();
        assert!(Message::decode(&payload[..payload.len() - 1]).is_err());
        let mut extra = payload.clone();
        extra.push(0);
        assert!(Message::decode(&extra).is_err());
    }

    #[test]
    fn rejects_bad_tags_and_oversize_frames() {
        assert!(Message::decode(&[99]).is_err());
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME + 1).to_le_bytes());
        assert!(Message::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn lying_counts_error_before_allocating() {
        // Partial frame claiming u32::MAX values with a 4-byte body:
        // must be a Protocol error, not a multi-GB Vec::with_capacity.
        let mut payload = vec![3u8]; // TAG_PARTIAL
        payload.extend_from_slice(&9u32.to_le_bytes()); // seq
        payload.push(0); // netflix=false
        payload.extend_from_slice(&1.0f32.to_le_bytes()); // weight
        payload.extend_from_slice(&u32::MAX.to_le_bytes()); // count lie
        payload.extend_from_slice(&[0u8; 4]);
        assert!(Message::decode(&payload).is_err());
        // Task frame with a huge block count
        let mut payload = vec![2u8]; // TAG_TASK
        payload.extend_from_slice(&1u32.to_le_bytes()); // seq
        payload.push(0); // workload tag
        payload.extend_from_slice(&7u64.to_le_bytes()); // seed
        payload.extend_from_slice(&0x00FF_FFFFu32.to_le_bytes());
        assert!(Message::decode(&payload).is_err());
    }

    #[test]
    fn garbage_payloads_never_panic() {
        // Fuzz decode over random byte strings — errors are fine,
        // panics and aborts are not.
        let mut rng = Rng::new(0xFEED);
        for _ in 0..2000 {
            let len = rng.below(64) as usize;
            let bytes: Vec<u8> =
                (0..len).map(|_| rng.below(256) as u8).collect();
            let _ = Message::decode(&bytes);
        }
        // and over mutated valid frames
        let good = Message::Partial {
            seq: 3,
            weight: 1.5,
            values: vec![0.5; 8],
            netflix: true,
        }
        .encode();
        for _ in 0..2000 {
            let mut bad = good.clone();
            let i = rng.below(bad.len() as u64) as usize;
            bad[i] ^= 1 << rng.below(8);
            let _ = Message::decode(&bad);
        }
    }

    #[test]
    fn truncated_header_is_an_error() {
        // read_from with fewer than 4 length bytes
        let two = [0u8, 1];
        assert!(Message::read_from(&mut &two[..]).is_err());
        // declared length longer than the stream
        let mut buf = Vec::new();
        buf.extend_from_slice(&10u32.to_le_bytes());
        buf.extend_from_slice(&[1, 2, 3]);
        assert!(Message::read_from(&mut &buf[..]).is_err());
    }

    #[test]
    fn workload_tags_round_trip() {
        for w in [Workload::Eaglet, Workload::NetflixHi, Workload::NetflixLo]
        {
            assert_eq!(workload_from(workload_tag(w)).unwrap(), w);
        }
        assert!(workload_from(7).is_err());
    }
}
