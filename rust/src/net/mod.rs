//! TCP leader/worker mode — the nc6-pipe stand-in (DESIGN.md §2).
//!
//! BashReduce connects map slots "through simple TCP pipes using the
//! nc6 tool"; here the leader (master node) owns the scheduler and
//! partitions data, pushing each task *with its input blocks inline* to
//! worker processes over length-prefixed frames, and collecting partials
//! back over the same socket. Workers execute through their local PJRT
//! runtime; Python never appears on either side.
//!
//! The in-process engine (`coordinator::run_job`) remains the primary
//! data plane (it exercises the dfs layer); this module exists so the
//! platform also runs as real separate processes (`bts leader` /
//! `bts worker`) and to price the wire protocol in the benches.

pub mod leader;
pub mod protocol;
pub mod worker;

pub use leader::{serve_job, LeaderReport};
pub use protocol::Message;
pub use worker::{run_worker, serve_connection};
