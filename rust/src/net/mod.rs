//! The wire layer: framed TCP for the transport spine (DESIGN.md §11).
//!
//! BashReduce connected map slots "through simple TCP pipes using the
//! nc6 tool"; the first reproduction of that idea here was a separate
//! leader/worker job path that pushed task data inline — and bypassed
//! the DFS, the cache, prefetching, and recovery entirely. That path
//! is retired: TCP is now just a transport under the one execution
//! spine (`exec` / `serve` over `transport::WorkerLink`s), and this
//! module keeps the wire-facing pieces:
//!
//! * [`protocol`] — the framed message grammar (magic + version +
//!   length header; control plane [`crate::transport::Down`]/
//!   [`crate::transport::Up`]; DFS block Get/Put/response messages),
//!   hardened against malformed frames and fuzzed.
//! * [`worker`] — the `bts worker --connect` entry point, a thin
//!   shell over [`crate::transport::run_remote_worker`].
//!
//! Leaders accept remote workers via `--listen`/`--workers-remote` on
//! `bts exec` and `bts serve` ([`crate::transport::RemoteWorkers`]).

pub mod protocol;
pub mod worker;

pub use protocol::Message;
pub use worker::run_worker;

use crate::error::{Error, Result};

/// The `bts drain <worker>` client: ask the leader at `addr` to drain
/// map slot `worker` gracefully (finish its running task, hand queued
/// work back, exit). The leader's membership acceptor echoes the frame
/// back as the ack; a non-elastic leader still acks and routes the
/// request — draining shrinks a membership, it never grows one.
pub fn request_drain(addr: &str, worker: u32) -> Result<()> {
    use std::io::{BufReader, BufWriter};
    use std::net::TcpStream;

    let stream = TcpStream::connect(addr).map_err(|e| {
        Error::Protocol(format!("connect to leader {addr}: {e}"))
    })?;
    protocol::configure_stream(&stream)?;
    let mut rd = BufReader::new(stream.try_clone()?);
    let mut wr = BufWriter::new(stream);
    Message::DrainWorker { worker }.write_to(&mut wr)?;
    match Message::read_deadline(
        &mut rd,
        Some(protocol::HANDSHAKE_TIMEOUT),
    )? {
        Message::DrainWorker { worker: w } if w == worker => Ok(()),
        Message::Error { message } => Err(Error::Protocol(message)),
        other => Err(Error::Protocol(format!(
            "unexpected drain ack: {other:?}"
        ))),
    }
}
