//! The wire layer: framed TCP for the transport spine (DESIGN.md §11).
//!
//! BashReduce connected map slots "through simple TCP pipes using the
//! nc6 tool"; the first reproduction of that idea here was a separate
//! leader/worker job path that pushed task data inline — and bypassed
//! the DFS, the cache, prefetching, and recovery entirely. That path
//! is retired: TCP is now just a transport under the one execution
//! spine (`exec` / `serve` over `transport::WorkerLink`s), and this
//! module keeps the wire-facing pieces:
//!
//! * [`protocol`] — the framed message grammar (magic + version +
//!   length header; control plane [`crate::transport::Down`]/
//!   [`crate::transport::Up`]; DFS block Get/Put/response messages),
//!   hardened against malformed frames and fuzzed.
//! * [`worker`] — the `bts worker --connect` entry point, a thin
//!   shell over [`crate::transport::run_remote_worker`].
//!
//! Leaders accept remote workers via `--listen`/`--workers-remote` on
//! `bts exec` and `bts serve` ([`crate::transport::RemoteWorkers`]).

pub mod protocol;
pub mod worker;

pub use protocol::Message;
pub use worker::run_worker;
