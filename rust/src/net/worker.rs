//! Worker process: connect to the leader, execute every task pushed at
//! it, stream partials back.
//!
//! The task loop is backend-agnostic ([`serve_connection`] is generic
//! over [`Exec`]): `bts worker` runs it over a per-process PJRT
//! [`Runtime`], and the native kernel backend (`exec::NativeExec` /
//! `exec::Backend`) plugs into the same loop on hosts without XLA.

use std::io::{BufReader, BufWriter};
use std::net::TcpStream;
use std::sync::Arc;

use super::protocol::Message;
use crate::coordinator::assemble::{execute_slices, MapTask, TaskPartial};
use crate::error::{Error, Result};
use crate::runtime::{Exec, Manifest, Runtime};

/// Connect to `addr`, announce as `worker_id`, and serve until Done
/// through a local PJRT runtime. Returns the number of tasks executed.
///
/// Connects (and sends Hello) *before* constructing the runtime: if
/// runtime init fails — e.g. a build linking the vendored xla stub —
/// the dropped stream surfaces as a read error at the leader, which
/// fails the job fast instead of waiting forever in `accept()`.
pub fn run_worker(
    addr: &str,
    worker_id: u32,
    manifest: Arc<Manifest>,
) -> Result<u64> {
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    let mut rd = BufReader::new(stream.try_clone()?);
    let mut wr = BufWriter::new(stream);
    Message::Hello { worker: worker_id }.write_to(&mut wr)?;
    let rt = Runtime::new(manifest)?;
    serve_frames(&rt, &mut rd, &mut wr)
}

/// Connect to `addr`, announce as `worker_id`, and execute every pushed
/// task through `rt` until the leader sends Done.
pub fn serve_connection(
    addr: &str,
    worker_id: u32,
    rt: &impl Exec,
) -> Result<u64> {
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    let mut rd = BufReader::new(stream.try_clone()?);
    let mut wr = BufWriter::new(stream);
    Message::Hello { worker: worker_id }.write_to(&mut wr)?;
    serve_frames(rt, &mut rd, &mut wr)
}

/// The task loop proper, over any framed transport.
fn serve_frames(
    rt: &impl Exec,
    mut rd: &mut impl std::io::Read,
    mut wr: &mut impl std::io::Write,
) -> Result<u64> {
    let p = rt.manifest().params.clone();
    let mut done: u64 = 0;
    loop {
        match Message::read_from(&mut rd)? {
            Message::Task { seq, workload, seed, blocks } => {
                let reply = (|| -> Result<Message> {
                    let slices =
                        MapTask::slices(&p, workload, &blocks, seed)?;
                    Ok(match execute_slices(rt, &p, slices)? {
                        TaskPartial::Eaglet { alod, weight } => {
                            Message::Partial {
                                seq,
                                weight,
                                values: alod,
                                netflix: false,
                            }
                        }
                        TaskPartial::Netflix { stats } => Message::Partial {
                            seq,
                            weight: 0.0,
                            values: stats,
                            netflix: true,
                        },
                    })
                })();
                match reply {
                    Ok(msg) => msg.write_to(&mut wr)?,
                    Err(e) => {
                        Message::Error { message: e.to_string() }
                            .write_to(&mut wr)?;
                        return Err(e);
                    }
                }
                done += 1;
            }
            Message::Done => return Ok(done),
            other => {
                return Err(Error::Protocol(format!(
                    "worker expected Task/Done, got {other:?}"
                )))
            }
        }
    }
}
