//! Worker process: connect to the leader, execute every task pushed at
//! it through the local PJRT runtime, stream partials back.

use std::io::{BufReader, BufWriter};
use std::net::TcpStream;
use std::sync::Arc;

use super::protocol::Message;
use crate::coordinator::assemble::{MapTask, TaskPartial};
use crate::error::{Error, Result};
use crate::runtime::{Manifest, Runtime};

/// Connect to `addr`, announce as `worker_id`, and serve until Done.
/// Returns the number of tasks executed.
pub fn run_worker(
    addr: &str,
    worker_id: u32,
    manifest: Arc<Manifest>,
) -> Result<u64> {
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    let mut rd = BufReader::new(stream.try_clone()?);
    let mut wr = BufWriter::new(stream);
    Message::Hello { worker: worker_id }.write_to(&mut wr)?;

    let p = manifest.params.clone();
    let rt = Runtime::new(manifest)?;
    let mut done: u64 = 0;
    loop {
        match Message::read_from(&mut rd)? {
            Message::Task { seq, workload, seed, blocks } => {
                let reply = (|| -> Result<Message> {
                    let slices =
                        MapTask::slices(&p, workload, &blocks, seed)?;
                    let mut parts = Vec::with_capacity(slices.len());
                    for s in &slices {
                        let e = rt
                            .manifest
                            .entry(s.kind, s.bucket)
                            .ok_or_else(|| {
                                Error::Artifact(format!(
                                    "no entry {} b{}",
                                    s.kind, s.bucket
                                ))
                            })?
                            .clone();
                        let out = rt.execute(&e, &s.inputs)?;
                        parts.push(TaskPartial::from_map_output(
                            &p, s, &out[0],
                        )?);
                    }
                    Ok(match TaskPartial::merge(parts)? {
                        TaskPartial::Eaglet { alod, weight } => {
                            Message::Partial {
                                seq,
                                weight,
                                values: alod,
                                netflix: false,
                            }
                        }
                        TaskPartial::Netflix { stats } => Message::Partial {
                            seq,
                            weight: 0.0,
                            values: stats,
                            netflix: true,
                        },
                    })
                })();
                match reply {
                    Ok(msg) => msg.write_to(&mut wr)?,
                    Err(e) => {
                        Message::Error { message: e.to_string() }
                            .write_to(&mut wr)?;
                        return Err(e);
                    }
                }
                done += 1;
            }
            Message::Done => return Ok(done),
            other => {
                return Err(Error::Protocol(format!(
                    "worker expected Task/Done, got {other:?}"
                )))
            }
        }
    }
}
