//! `bts worker --connect`: a remote map slot as a separate process.
//!
//! This is deliberately a thin shell: all behavior lives in
//! [`crate::transport::run_remote_worker`], which connects, handshakes
//! (Hello → Welcome, slot assigned by the leader), and runs the same
//! [`crate::transport::worker_body`] every in-proc map slot runs —
//! two-step scheduler batches, prefetching through the leader-proxied
//! DFS path, per-task metrics, and job-level recovery all come from
//! the shared spine, not from anything TCP-specific here.

use std::sync::Arc;

use crate::error::Result;
use crate::exec::Backend;
use crate::transport::{run_remote_worker, RemoteWorkerOpts};

/// Connect to a leader at `addr` and serve one worker session through
/// `backend`. Returns the number of tasks executed (the session ends
/// when the leader sends `Shutdown` or the link dies).
pub fn run_worker(
    addr: &str,
    backend: Arc<Backend>,
    opts: &RemoteWorkerOpts,
) -> Result<u64> {
    run_remote_worker(addr, backend, opts)
}
