//! Leader: owns the dataset, the packer and the two-step scheduler;
//! pushes tasks (data inline) to connected workers and reduces the
//! partials it collects.

use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};

use super::protocol::Message;
use crate::coordinator::reduce::{
    finalize_netflix, reduce_eaglet, reduce_netflix,
};
use crate::coordinator::JobOutput;
use crate::data::{Dataset, Workload};
use crate::error::{Error, Result};
use crate::kneepoint::TaskSizing;
use crate::metrics::Timer;
use crate::runtime::{Manifest, Runtime};
use crate::scheduler::{SchedConfig, TaskSpec, TwoStepScheduler};

/// What a finished distributed job reports.
#[derive(Debug, Clone)]
pub struct LeaderReport {
    pub output: JobOutput,
    pub tasks: usize,
    pub workers: usize,
    pub total_s: f64,
    pub bytes_shipped: usize,
}

/// Serve one job to `workers` connecting worker processes, then reduce.
///
/// `listener` should already be bound (letting the caller pick port 0
/// for tests). Blocks until the job completes.
pub fn serve_job(
    listener: TcpListener,
    dataset: &dyn Dataset,
    manifest: Arc<Manifest>,
    sizing: TaskSizing,
    workers: usize,
    seed: u64,
) -> Result<LeaderReport> {
    let timer = Timer::start();
    let workload = dataset.workload();
    let tasks = crate::kneepoint::pack(dataset.metas(), sizing);
    let n_tasks = tasks.len();
    let specs: Vec<TaskSpec> = tasks
        .into_iter()
        .map(|t| TaskSpec::new(t, workload, seed))
        .collect();
    let sched =
        TwoStepScheduler::new(specs, workers, SchedConfig::default());

    // Accept exactly `workers` connections (Hello handshake).
    let mut conns: Vec<TcpStream> = Vec::with_capacity(workers);
    for _ in 0..workers {
        let (stream, _addr) = listener.accept()?;
        stream.set_nodelay(true).ok();
        let mut rd = BufReader::new(stream.try_clone()?);
        match Message::read_from(&mut rd)? {
            Message::Hello { .. } => conns.push(stream),
            other => {
                return Err(Error::Protocol(format!(
                    "expected Hello, got {other:?}"
                )))
            }
        }
    }

    let partials: Mutex<Vec<Option<(f32, Vec<f32>)>>> =
        Mutex::new(vec![None; n_tasks]);
    let shipped = Mutex::new(0usize);
    let mut first_err: Option<Error> = None;

    std::thread::scope(|sc| {
        let mut handles = Vec::new();
        for (w, stream) in conns.into_iter().enumerate() {
            let sched = &sched;
            let partials = &partials;
            let shipped = &shipped;
            handles.push(sc.spawn(move || -> Result<()> {
                let mut rd = BufReader::new(stream.try_clone()?);
                let mut wr = BufWriter::new(stream);
                while let Some(spec) = sched.next(w) {
                    let blocks: Vec<_> = spec
                        .task
                        .sample_ids
                        .iter()
                        .map(|&id| dataset.encode_block(id))
                        .collect();
                    let msg = Message::Task {
                        seq: spec.task.seq as u32,
                        workload: spec.workload,
                        seed: spec.seed,
                        blocks,
                    };
                    let t = Timer::start();
                    *shipped.lock().unwrap() += spec.task.bytes;
                    msg.write_to(&mut wr)?;
                    match Message::read_from(&mut rd)? {
                        Message::Partial { seq, weight, values, .. } => {
                            partials.lock().unwrap()[seq as usize] =
                                Some((weight, values));
                        }
                        Message::Error { message } => {
                            return Err(Error::Protocol(format!(
                                "worker {w}: {message}"
                            )))
                        }
                        other => {
                            return Err(Error::Protocol(format!(
                                "expected Partial, got {other:?}"
                            )))
                        }
                    }
                    // round-trip time feeds the feedback loop as "exec"
                    sched.report(w, 0.0, t.secs());
                }
                Message::Done.write_to(&mut wr)?;
                Ok(())
            }));
        }
        for h in handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => first_err = Some(e),
                Err(_) => {
                    first_err =
                        Some(Error::Protocol("leader thread panicked".into()))
                }
            }
        }
    });
    if let Some(e) = first_err {
        return Err(e);
    }

    // Reduce on the leader through the same artifacts.
    let collected: Vec<(f32, Vec<f32>)> = partials
        .into_inner()
        .unwrap()
        .into_iter()
        .enumerate()
        .map(|(seq, p)| {
            p.ok_or_else(|| {
                Error::Protocol(format!("no partial for task {seq}"))
            })
        })
        .collect::<Result<_>>()?;
    let rt = Runtime::new(manifest.clone())?;
    let p = &manifest.params;
    let output = match workload {
        Workload::Eaglet => {
            let (alod, weight) = reduce_eaglet(
                &rt,
                p,
                collected.into_iter().map(|(w, v)| (v, w)).collect(),
            )?;
            JobOutput::Eaglet { alod, weight }
        }
        _ => {
            let stats = reduce_netflix(
                &rt,
                p,
                collected.into_iter().map(|(_, v)| v).collect(),
            )?;
            JobOutput::Netflix(finalize_netflix(p, &stats)?)
        }
    };
    Ok(LeaderReport {
        output,
        tasks: n_tasks,
        workers,
        total_s: timer.secs(),
        bytes_shipped: shipped.into_inner().unwrap(),
    })
}
