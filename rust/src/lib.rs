//! # subsample-bts
//!
//! Production-grade reproduction of *"An Efficient and Balanced Platform
//! for Data-Parallel Subsampling Workloads"* (Kambhampati, OSU MS thesis,
//! 2014): a data-parallel platform ("BTS") that sizes map tasks at the
//! kneepoint of the task-size → cache-miss-rate curve, schedules the
//! resulting *tiny tasks* with a two-step feedback scheduler, serves
//! their data from an adaptively-replicated in-memory store, and uses
//! job-level (not task-level) recovery.
//!
//! Three-layer architecture (DESIGN.md §3): this crate is Layer 3 — the
//! rust coordinator that owns the event loop, scheduling, data
//! distribution and metrics. The map/reduce statistics themselves are
//! JAX + Pallas programs (python/compile/), AOT-lowered to HLO text and
//! executed through the PJRT CPU client (`runtime`). Python never runs
//! on the request path.
//!
//! ```text
//! job → kneepoint::pack → scheduler::TwoStep → worker: dfs fetch →
//!       runtime::execute(map artifact) → shuffle → runtime::execute
//!       (reduce artifact, tree) → finalize
//! ```
//!
//! Two executors drive that pipeline: `coordinator::job` (scoped
//! threads pulling from the shared scheduler, PJRT artifacts) and
//! `exec` (a leader plus N workers over channels, generic over the
//! kernel backend — compiled artifacts or the pure-rust `exec::native`
//! kernels, so jobs run end to end on hosts without XLA; DESIGN.md §4).

pub mod cache;
pub mod cachesim;
pub mod coordinator;
pub mod data;
pub mod dfs;
pub mod error;
pub mod exec;
pub mod federation;
pub mod figures;
pub mod kneepoint;
pub mod config;
pub mod membership;
pub mod metrics;
pub mod net;
pub mod platforms;
pub mod reduce;
pub mod runtime;
pub mod scheduler;
pub mod serve;
pub mod sim;
pub mod slo;
pub mod suite;
pub mod transport;
pub mod util;
pub mod workloads;

pub use error::{Error, Result};
