//! SLO planner (§4.2.3, Fig 13): pick the cluster configuration with the
//! highest achieved throughput whose running time fits a fixed bound,
//! and advise scale-out only "until additional cores provide diminishing
//! returns and no further" (Fig 12's management takeaway).

use std::collections::HashMap;
use std::sync::Mutex;

use crate::data::Workload;
use crate::platforms::PlatformSpec;
use crate::sim::{default_params, simulate, Cluster, HardwareType};

/// One candidate configuration's simulated outcome.
#[derive(Debug, Clone)]
pub struct PlanPoint {
    pub cores: usize,
    pub job_bytes: usize,
    pub total_s: f64,
    pub throughput_mbs: f64,
}

/// The planner's answer for one SLO bound.
#[derive(Debug, Clone)]
pub struct SloPlan {
    pub slo_s: f64,
    pub best: PlanPoint,
    /// Fraction of the no-SLO peak throughput this plan achieves (the
    /// Fig-13 y-axis: 2-minute SLO → ~50%, 5-minute → ~83%).
    pub frac_of_peak: f64,
}

/// Hardware used for planning (the thesis's type-2 Xeons).
fn cluster_of(cores: usize) -> Cluster {
    Cluster::homogeneous(HardwareType::TypeII, cores.div_ceil(12).max(1))
}

/// Highest-throughput (cores, job size) whose simulated running time is
/// ≤ `slo_s`. Mirrors Fig 13: "Each result reflects the platform
/// configuration with highest achieved throughput within the fixed
/// running time."
pub fn best_under_slo(
    workload: Workload,
    slo_s: f64,
    core_options: &[usize],
    job_sizes: &[usize],
    compute_s_per_mib: f64,
) -> Option<SloPlan> {
    let mut best: Option<PlanPoint> = None;
    let mut peak = 0.0f64;
    for &cores in core_options {
        let cluster = cluster_of(cores);
        for &job in job_sizes {
            let p = default_params(workload, job, compute_s_per_mib);
            let r = simulate(&PlatformSpec::bts(), &cluster, &p);
            peak = peak.max(r.throughput_mbs);
            if r.total_s <= slo_s
                && best
                    .as_ref()
                    .map(|b| r.throughput_mbs > b.throughput_mbs)
                    .unwrap_or(true)
            {
                best = Some(PlanPoint {
                    cores,
                    job_bytes: job,
                    total_s: r.total_s,
                    throughput_mbs: r.throughput_mbs,
                });
            }
        }
    }
    best.map(|b| SloPlan {
        slo_s,
        frac_of_peak: if peak > 0.0 { b.throughput_mbs / peak } else { 0.0 },
        best: b,
    })
}

/// Planner-backed wall-time estimate for one job: simulated total
/// running time of `job_bytes` on `cores` map slots, using the same
/// thesis-scale platform model as [`best_under_slo`]. This is the
/// serve layer's admission signal — a *model* figure used to order
/// the queue (EDF) and reject deadlines no configuration could meet,
/// not a prediction of local wall-clock.
pub fn estimate_job_s(
    workload: Workload,
    job_bytes: usize,
    cores: usize,
    compute_s_per_mib: f64,
) -> f64 {
    let p = default_params(workload, job_bytes, compute_s_per_mib);
    simulate(&PlatformSpec::bts(), &cluster_of(cores.max(1)), &p).total_s
}

/// Memoizing wrapper over [`estimate_job_s`] for admission at
/// federation scale. The platform simulation is deterministic in its
/// inputs, and a front-door fielding thousands of tenants sees only a
/// handful of distinct `(workload, job_bytes, cores)` shapes — so the
/// per-submission admission check amortizes to a map lookup instead
/// of a fresh simulation per tenant.
#[derive(Debug, Default)]
pub struct EstimateCache {
    map: Mutex<HashMap<(Workload, usize, usize, u64), f64>>,
}

impl EstimateCache {
    pub fn new() -> EstimateCache {
        EstimateCache::default()
    }

    /// [`estimate_job_s`], memoized on the full input tuple
    /// (`compute_s_per_mib` keyed by its exact bits).
    pub fn estimate_s(
        &self,
        workload: Workload,
        job_bytes: usize,
        cores: usize,
        compute_s_per_mib: f64,
    ) -> f64 {
        let key =
            (workload, job_bytes, cores, compute_s_per_mib.to_bits());
        if let Some(&v) = self.map.lock().unwrap().get(&key) {
            return v;
        }
        // Simulate outside the lock: a cold key must not serialize
        // every other submitter behind the simulation.
        let v = estimate_job_s(workload, job_bytes, cores, compute_s_per_mib);
        self.map.lock().unwrap().insert(key, v);
        v
    }

    /// Distinct job shapes estimated so far.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Smallest core count achieving ≥ `frac` of the best simulated
/// throughput at this job size — the "scale out until diminishing
/// returns" advisor.
pub fn min_cores_for(
    workload: Workload,
    job_bytes: usize,
    core_options: &[usize],
    frac: f64,
    compute_s_per_mib: f64,
) -> Option<usize> {
    let results: Vec<(usize, f64)> = core_options
        .iter()
        .map(|&cores| {
            let p = default_params(workload, job_bytes, compute_s_per_mib);
            let r = simulate(&PlatformSpec::bts(), &cluster_of(cores), &p);
            (cores, r.throughput_mbs)
        })
        .collect();
    let best = results.iter().map(|r| r.1).fold(0.0, f64::max);
    results
        .iter()
        .filter(|(_, t)| *t >= best * frac)
        .map(|(c, _)| *c)
        .min()
}

#[cfg(test)]
mod tests {
    use super::*;

    const CORES: [usize; 3] = [12, 36, 72];

    fn jobs() -> Vec<usize> {
        [8, 32, 128, 512, 2048, 8192]
            .iter()
            .map(|mb| mb * 1024 * 1024)
            .collect()
    }

    #[test]
    fn looser_slo_never_hurts_throughput() {
        let tight = best_under_slo(
            Workload::Eaglet, 30.0, &CORES, &jobs(), 0.06,
        )
        .unwrap();
        let loose = best_under_slo(
            Workload::Eaglet, 600.0, &CORES, &jobs(), 0.06,
        )
        .unwrap();
        assert!(loose.best.throughput_mbs >= tight.best.throughput_mbs);
        assert!(loose.frac_of_peak >= tight.frac_of_peak);
        assert!(tight.best.total_s <= 30.0);
    }

    #[test]
    fn tight_slo_prefers_fewer_cores_or_smaller_jobs() {
        // Fig 13: under tight bounds the 72-core config's startup costs
        // push the planner to smaller configurations.
        let plan =
            best_under_slo(Workload::Eaglet, 10.0, &CORES, &jobs(), 0.06);
        if let Some(p) = plan {
            assert!(p.best.total_s <= 10.0);
            assert!(p.frac_of_peak <= 1.0);
        }
    }

    #[test]
    fn min_cores_finds_diminishing_returns() {
        // On a small job, 72 cores shouldn't be needed to hit 90% of peak.
        let c = min_cores_for(
            Workload::Eaglet,
            16 * 1024 * 1024,
            &CORES,
            0.90,
            0.06,
        )
        .unwrap();
        assert!(c <= 36, "small jobs should not need 72 cores, got {c}");
        // On a big job, more cores should genuinely be selected.
        let c_big = min_cores_for(
            Workload::Eaglet,
            4 * 1024 * 1024 * 1024,
            &CORES,
            0.90,
            0.06,
        )
        .unwrap();
        assert!(c_big >= c);
    }

    #[test]
    fn estimate_is_positive_and_monotone_in_job_size() {
        let small =
            estimate_job_s(Workload::Eaglet, 16 * 1024 * 1024, 4, 0.06);
        let big =
            estimate_job_s(Workload::Eaglet, 1024 * 1024 * 1024, 4, 0.06);
        assert!(small > 0.0);
        assert!(big > small, "more data must cost more time");
        // zero cores clamps rather than dividing by zero
        assert!(estimate_job_s(Workload::Eaglet, 1024, 0, 0.06) > 0.0);
    }

    #[test]
    fn estimate_cache_matches_uncached_and_dedups() {
        let cache = EstimateCache::new();
        assert!(cache.is_empty());
        let direct =
            estimate_job_s(Workload::Eaglet, 16 * 1024 * 1024, 4, 0.06);
        for _ in 0..3 {
            let cached =
                cache.estimate_s(Workload::Eaglet, 16 * 1024 * 1024, 4, 0.06);
            assert_eq!(cached, direct, "cache must not change the answer");
        }
        assert_eq!(cache.len(), 1, "identical shapes share one entry");
        let other =
            cache.estimate_s(Workload::NetflixHi, 16 * 1024 * 1024, 4, 0.06);
        assert_eq!(cache.len(), 2);
        assert_eq!(
            other,
            estimate_job_s(Workload::NetflixHi, 16 * 1024 * 1024, 4, 0.06)
        );
    }

    #[test]
    fn impossible_slo_returns_none() {
        let plan = best_under_slo(
            Workload::Eaglet,
            1e-6,
            &CORES,
            &jobs(),
            0.06,
        );
        assert!(plan.is_none());
    }
}
