//! Closed-loop sustained-load generator: Poisson arrivals over a mixed
//! EAGLET/Netflix job set, driven to completion against a
//! [`JobService`]. `bts serve`, `examples/serve_load.rs` and
//! `benches/serve_throughput.rs` all run this one harness so the
//! numbers they report are the same experiment.
//!
//! The mix deliberately includes a slice of deadline-infeasible
//! requests (`infeasible_every`): a service whose admission control
//! never fires is a service whose admission control is untested.

use std::sync::Arc;
use std::time::Duration;

use super::admission::JobRequest;
use super::pool::PoolConfig;
use super::service::{JobResult, JobService, ServeConfig, ServeReport};
use crate::data::Workload;
use crate::error::{Error, Result};
use crate::exec::Backend;
use crate::kneepoint::TaskSizing;
use crate::util::rng::Rng;

/// Shape of one sustained-load session.
#[derive(Debug, Clone)]
pub struct LoadConfig {
    /// Total submissions (admitted + rejected).
    pub jobs: usize,
    pub workers: usize,
    /// Jobs multiplexed concurrently.
    pub max_active: usize,
    /// Poisson arrival rate, jobs per second (mean inter-arrival is
    /// `1/rate`; `f64::INFINITY` submits back to back).
    pub arrival_rate_per_s: f64,
    pub seed: u64,
    /// Baseline dataset size; each job draws samples in
    /// `[base_samples, 1.5 * base_samples)`.
    pub base_samples: usize,
    /// Every Nth job asks for a deadline no configuration can meet and
    /// must be rejected at admission. 0 disables.
    pub infeasible_every: usize,
    /// Shared block cache budget in MiB for the pool (0 disables).
    pub cache_mb: usize,
    /// Cache-affinity dispatch across the warm pool.
    pub affinity: bool,
    /// Speculative re-execution of straggling tasks (`--speculate`);
    /// implies response-time-aware dynamic scheduling.
    pub speculate: bool,
    /// Straggler threshold quantile in percent (`--straggler-pct`).
    pub straggler_pct: f64,
    /// Remote TCP map slots for the pool (`bts serve --listen
    /// --workers-remote`): accepted once at pool start, serving every
    /// tenant of the session.
    pub remote: Option<crate::transport::RemoteWorkers>,
    /// Elastic membership (`--elastic`): admit late joiners for the
    /// whole session and absorb drains/losses via the task ledger.
    pub elastic: bool,
    /// Remote-link heartbeat cadence in ms (`--heartbeat-ms`).
    pub heartbeat_ms: u64,
    /// Dispatcher poll cadence in ms (`--straggler-poll-ms`).
    pub straggler_poll_ms: u64,
}

impl Default for LoadConfig {
    fn default() -> Self {
        LoadConfig {
            jobs: 20,
            workers: 4,
            max_active: 4,
            arrival_rate_per_s: 25.0,
            seed: 0xB75,
            base_samples: 40,
            infeasible_every: 5,
            cache_mb: 0,
            affinity: false,
            speculate: false,
            straggler_pct: 95.0,
            remote: None,
            elastic: false,
            heartbeat_ms: crate::net::protocol::PING_INTERVAL.as_millis()
                as u64,
            straggler_poll_ms: crate::scheduler::SPECULATION_POLL
                .as_millis() as u64,
        }
    }
}

/// What a finished load session hands back. Admission rejections are
/// counted once, in `report.jobs_rejected`.
pub struct LoadOutcome {
    pub report: ServeReport,
    pub results: Vec<JobResult>,
}

/// The `i`-th request of the mixed job set for `cfg` (deterministic in
/// `(cfg.seed, i)` — callers replay any job solo from its index).
pub fn mixed_request(cfg: &LoadConfig, i: usize) -> JobRequest {
    let mut rng = Rng::new(cfg.seed ^ (i as u64).wrapping_mul(0x9E37));
    let workload = match i % 3 {
        0 => Workload::Eaglet,
        1 => Workload::NetflixHi,
        _ => Workload::NetflixLo,
    };
    let samples = cfg.base_samples
        + rng.below((cfg.base_samples as u64 / 2).max(1)) as usize;
    let infeasible = cfg.infeasible_every > 0
        && (i + 1) % cfg.infeasible_every == 0;
    let deadline_s = if infeasible {
        // No platform configuration simulates below a millisecond.
        Some(1e-3)
    } else if i % 2 == 0 {
        // Generous but real deadlines exercise the EDF path.
        Some(3600.0 + (i as f64) * 60.0)
    } else {
        None
    };
    JobRequest {
        workload,
        samples,
        sizing: TaskSizing::Kneepoint(32 * 1024),
        seed: cfg.seed ^ ((i as u64) << 8),
        deadline_s,
        max_attempts: 3,
        fault: None,
        reduce_tasks: 1,
        partitioner: crate::reduce::Partitioner::Hash,
    }
}

/// Run the session: start a service, submit `cfg.jobs` requests with
/// exponential inter-arrival gaps, wait for every admitted job, drain.
pub fn run_load(
    backend: Arc<Backend>,
    cfg: &LoadConfig,
) -> Result<LoadOutcome> {
    let sched = crate::scheduler::SchedConfig {
        dynamic: cfg.speculate,
        speculate: cfg.speculate,
        straggler_pct: cfg.straggler_pct,
        straggler_poll_ms: cfg.straggler_poll_ms,
        ..Default::default()
    };
    let svc = JobService::start(
        backend,
        ServeConfig {
            pool: PoolConfig {
                workers: cfg.workers,
                cache_mb: cfg.cache_mb,
                affinity: cfg.affinity,
                remote: cfg.remote.clone(),
                elastic: cfg.elastic,
                heartbeat_ms: cfg.heartbeat_ms,
                ..Default::default()
            },
            max_active: cfg.max_active,
            sched,
            ..Default::default()
        },
    )?;
    let mut rng = Rng::new(cfg.seed);
    let mut handles = Vec::new();
    for i in 0..cfg.jobs {
        let req = mixed_request(cfg, i);
        match svc.submit(req) {
            Ok(h) => handles.push(h),
            // expected for the infeasible slice; the service counts it
            Err(Error::Admission(_)) => {}
            Err(e) => return Err(e),
        }
        if cfg.arrival_rate_per_s.is_finite()
            && cfg.arrival_rate_per_s > 0.0
            && i + 1 < cfg.jobs
        {
            let gap = rng.exp(cfg.arrival_rate_per_s);
            std::thread::sleep(Duration::from_secs_f64(gap.min(0.25)));
        }
    }
    // Bounded waits: a wedged dispatcher surfaces as one failed job
    // (naming the deadline) instead of hanging every caller of the
    // harness — `bts serve`, the CI smoke example, and the benches.
    let results: Vec<JobResult> = handles
        .into_iter()
        .map(|h| h.wait_timeout(crate::util::testutil::SERVE_JOB_DEADLINE))
        .collect::<Result<_>>()?;
    let report = svc.shutdown()?;
    Ok(LoadOutcome { report, results })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixed_requests_are_deterministic_and_mixed() {
        let cfg = LoadConfig::default();
        let a: Vec<JobRequest> =
            (0..12).map(|i| mixed_request(&cfg, i)).collect();
        let b: Vec<JobRequest> =
            (0..12).map(|i| mixed_request(&cfg, i)).collect();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.workload, y.workload);
            assert_eq!(x.samples, y.samples);
            assert_eq!(x.seed, y.seed);
            assert_eq!(x.deadline_s, y.deadline_s);
        }
        // all three workloads appear
        for w in
            [Workload::Eaglet, Workload::NetflixHi, Workload::NetflixLo]
        {
            assert!(a.iter().any(|r| r.workload == w));
        }
        // the infeasible slice exists and is actually infeasible-tight
        let infeasible: Vec<&JobRequest> = a
            .iter()
            .filter(|r| r.deadline_s.is_some_and(|d| d < 0.01))
            .collect();
        assert!(!infeasible.is_empty());
    }
}
