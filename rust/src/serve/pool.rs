//! The persistent worker pool: map slots, prefetchers, and the shared
//! replicated store stay warm across jobs.
//!
//! This is the half of the thesis's "interactive subsampling" promise
//! the one-shot executor could not keep: `exec::run_cluster` pays
//! spawn/stage/join on every job, exactly the startup overhead Figs
//! 5–6 say must stay small. Pool workers are spawned once, serve tasks
//! from *any* job (each task carries its job id, attempt, and key
//! namespace), and exit only at service shutdown — the pool's
//! `spawned` count never grows past its slot count, which the serve
//! tests assert as the warm-pool invariant.
//!
//! Since the transport refactor the pool holds
//! [`WorkerLink`]s, not join handles: local slots are threads running
//! the shared [`crate::transport::worker_body`], and
//! [`PoolConfig::remote`] slots are `bts worker --connect` processes
//! adopted over framed TCP at pool start — same body, same message
//! grammar, DFS-proxied data plane. The dispatcher above cannot tell
//! them apart.
//!
//! Failure semantics differ from the solo executor on purpose: a task
//! error is reported as [`Up::TaskFailed`] and the worker *keeps
//! running* — one tenant's bad job must not take map slots away from
//! the others. The dispatcher aborts and restarts just that job
//! (job-level recovery, scoped to the tenant). A *link* death
//! ([`Up::Lost`] — e.g. a remote worker dropping mid-job) retires the
//! slot and restarts the jobs it may have been carrying.

use std::sync::mpsc;
use std::sync::Arc;

use crate::cache::{AffinityIndex, CacheLayer};
use crate::data::ModelParams;
use crate::dfs::{Dfs, LatencyModel};
use crate::error::{Error, Result};
use crate::exec::Backend;
use crate::membership::{Acceptor, MemberEvent};
use crate::net::protocol::{
    NetCounters, NetTotals, ACCEPT_TIMEOUT, PING_INTERVAL,
};
use crate::scheduler::ResponseTimeTracker;
use crate::transport::{
    teardown, BodyCfg, Down, PumpCfg, RemoteWorkers, Up, WorkerLink,
};
use crate::util::testutil::Turbulence;

/// Shape of the persistent pool backing a [`super::JobService`].
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Local worker threads (map slots shared by every in-flight job).
    pub workers: usize,
    /// Remote TCP map slots, accepted once at pool start and serving
    /// every tenant until service shutdown (slot indices after the
    /// local ones).
    pub remote: Option<RemoteWorkers>,
    /// Data nodes backing the shared replicated store.
    pub data_nodes: usize,
    /// Replication factor for staged blocks (fixed for the pool's
    /// lifetime; the per-job adaptive controller is a solo-run feature).
    pub replication_factor: usize,
    pub latency: LatencyModel,
    /// Upper bound on each worker's prefetch depth k.
    pub prefetch_k: usize,
    /// Shared block cache budget in MiB (0 disables). The cache is
    /// keyed by content hash, so concurrent tenants staging identical
    /// sample blocks dedupe instead of double-fetching.
    pub cache_mb: usize,
    /// Cache-affinity dispatch across the warm pool.
    pub affinity: bool,
    /// Deterministic latency/fault turbulence for the pool's in-proc
    /// slots (scheduler/speculation tests).
    pub turbulence: Option<Arc<Turbulence>>,
    /// Elastic membership (DESIGN.md §14): keep admitting late `bts
    /// worker --connect`s for the pool's whole life, absorb `bts
    /// drain` departures, and turn worker loss into a per-tenant
    /// ledger re-dispatch instead of tenant restarts. Off, the
    /// membership freezes at pool start and late joiners get a
    /// versioned refusal frame.
    pub elastic: bool,
    /// Remote-link heartbeat interval in milliseconds (ping cadence;
    /// ×6 is the pump's silent-peer threshold).
    pub heartbeat_ms: u64,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            workers: 4,
            remote: None,
            data_nodes: 4,
            replication_factor: 2,
            latency: LatencyModel::none(),
            prefetch_k: 8,
            cache_mb: 0,
            affinity: false,
            turbulence: None,
            elastic: false,
            heartbeat_ms: PING_INTERVAL.as_millis() as u64,
        }
    }
}

impl PoolConfig {
    /// Total map slots: local threads plus remote TCP workers.
    pub fn slots(&self) -> usize {
        self.workers + self.remote.as_ref().map_or(0, |r| r.count)
    }
}

/// A spawned-once pool of worker links over one shared store.
/// `spawned` equals the slot count for the pool's whole life — there
/// is no respawn path — and the serve report surfaces both so tests
/// can assert the "zero respawns between jobs" warm-pool invariant.
pub(crate) struct WorkerPool {
    pub(crate) dfs: Arc<Dfs>,
    /// Total map slots (local + remote).
    pub(crate) workers: usize,
    pub(crate) spawned: usize,
    /// Shared affinity registry (None unless `PoolConfig::affinity`).
    pub(crate) affinity: Option<Arc<AffinityIndex>>,
    /// Pool-lifetime response-time tracker: every tenant's `JobCtx`
    /// shares it, so warm slots carry their observed speed (and remote
    /// links their heartbeat drag) across jobs — a freshly admitted
    /// job already knows which slot is the straggler.
    pub(crate) tracker: Arc<ResponseTimeTracker>,
    /// Elastic membership policy (from [`PoolConfig::elastic`]): with
    /// it on, worker departures take the per-tenant ledger re-dispatch
    /// path instead of tenant restarts.
    pub(crate) elastic: bool,
    /// Pool-lifetime wire counters: every adopted link's pump reports
    /// into them, so the serve report can surface data-plane volume
    /// (zero for purely in-proc pools — mpsc is not a wire).
    net: Arc<NetCounters>,
    links: Vec<WorkerLink>,
    /// Pool-lifetime accept loop (remote pools only). Holds the
    /// listener open past the initial quota so late joiners are
    /// admitted (elastic) or refused with a versioned frame (static)
    /// instead of hanging in `connect`.
    acceptor: Option<Acceptor>,
}

impl WorkerPool {
    /// Stand the pool up: spawn the local slots, adopt the remote
    /// ones. `up` is the dispatcher's channel; every worker reports
    /// completions, failures and its exit through it.
    pub(crate) fn new(
        cfg: &PoolConfig,
        params: ModelParams,
        backend: Arc<Backend>,
        up: mpsc::Sender<Up>,
    ) -> Result<WorkerPool> {
        let slots = cfg.slots();
        if slots == 0 {
            return Err(Error::Config(
                "pool needs at least one worker (local or remote)".into(),
            ));
        }
        let dfs = Dfs::new(
            cfg.data_nodes.max(1),
            cfg.replication_factor.max(1),
            cfg.latency.clone(),
        );
        let layer = CacheLayer::build(&dfs, cfg.cache_mb, cfg.affinity);
        let tracker = Arc::new(ResponseTimeTracker::new());
        let mut links = Vec::with_capacity(slots);
        for w in 0..cfg.workers {
            let body = BodyCfg {
                worker: w,
                prefetch_k: cfg.prefetch_k,
                failure: None,
                // Pool semantics: survive task errors, serve the next
                // tenant.
                survive_task_errors: true,
                affinity: layer.affinity.clone(),
                turbulence: cfg.turbulence.clone(),
            };
            links.push(WorkerLink::spawn_inproc(
                body,
                params.clone(),
                backend.clone(),
                dfs.clone(),
                up.clone(),
                "bts-serve-worker",
            )?);
        }
        let mut acceptor = None;
        let net = Arc::new(NetCounters::default());
        if let Some(remote) = &cfg.remote {
            let acc = match Acceptor::spawn(
                remote.listener.clone(),
                cfg.workers,
                remote.count,
                cfg.elastic,
                dfs.clone(),
                up.clone(),
                Some(tracker.clone()),
                PumpCfg::from_heartbeat_ms(cfg.heartbeat_ms),
                net.clone(),
            ) {
                Ok(acc) => acc,
                Err(e) => {
                    teardown(links);
                    return Err(e);
                }
            };
            // The initial quota is still a synchronous barrier: the
            // pool is not up until every promised remote slot is.
            while links.len() < cfg.workers + remote.count {
                match acc.wait_event(ACCEPT_TIMEOUT) {
                    Some(MemberEvent::Joined(link)) => links.push(link),
                    // No tenants yet, nothing to drain.
                    Some(MemberEvent::DrainRequested(_)) => {}
                    None => {
                        acc.stop();
                        teardown(links);
                        return Err(Error::Protocol(format!(
                            "timed out waiting for the initial {} remote \
                             worker(s)",
                            remote.count
                        )));
                    }
                }
            }
            acceptor = Some(acc);
        }
        let spawned = links.len();
        Ok(WorkerPool {
            dfs,
            workers: slots,
            spawned,
            affinity: layer.affinity,
            tracker,
            elastic: cfg.elastic,
            net,
            links,
            acceptor,
        })
    }

    /// Snapshot of the pool's wire counters (service-lifetime totals).
    pub(crate) fn net_totals(&self) -> NetTotals {
        self.net.totals()
    }

    /// Next queued membership event, if any (non-blocking). `None`
    /// when the pool has no listener or nothing is waiting.
    pub(crate) fn try_member_event(&self) -> Option<MemberEvent> {
        self.acceptor.as_ref().and_then(|a| a.try_event())
    }

    /// Whether a departed slot can ever be replaced: elastic policy
    /// with a live accept loop. When `false`, an all-dead pool is
    /// terminal and the dispatcher fails its tenants immediately.
    pub(crate) fn can_rejoin(&self) -> bool {
        self.elastic && self.acceptor.is_some()
    }

    /// Absorb an already-handshaken joiner as the next slot. The
    /// acceptor hands out slot indices sequentially, so the link's
    /// slot is exactly `links.len()`. `spawned` grows with it — a
    /// join is a new worker, not a respawn, and the warm-pool
    /// invariant (`spawned - workers == 0`) still holds.
    pub(crate) fn admit(&mut self, link: WorkerLink) -> usize {
        let w = self.links.len();
        self.links.push(link);
        self.workers += 1;
        self.spawned += 1;
        w
    }

    /// Push a message to one worker. `false` means the worker's link
    /// is gone (its `Up::Lost`/`Exited` explains).
    pub(crate) fn send(&self, worker: usize, msg: Down) -> bool {
        self.links[worker].send(msg)
    }

    /// Broadcast a job abort to every worker.
    pub(crate) fn abort(&self, job: u64, upto_attempt: u32) {
        for l in &self.links {
            let _ = l.send(Down::Abort { job, upto_attempt });
        }
    }

    /// Tell every worker to exit and join the links. The caller
    /// drains the up-channel for [`Up::Exited`] accounting. The
    /// accept loop stops first so no joiner is adopted into a pool
    /// that is tearing down.
    pub(crate) fn shutdown(self) {
        if let Some(acc) = self.acceptor {
            acc.stop();
        }
        teardown(self.links);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Workload;
    use crate::dfs::job_ns;
    use crate::kneepoint::{pack, TaskSizing};
    use crate::scheduler::TaskSpec;
    use crate::transport::TaskEnvelope;

    #[test]
    fn zero_worker_pool_is_a_config_error() {
        let (tx, _rx) = mpsc::channel();
        let backend = Arc::new(Backend::native(ModelParams::default()));
        let cfg = PoolConfig { workers: 0, ..Default::default() };
        assert!(
            WorkerPool::new(&cfg, ModelParams::default(), backend, tx)
                .is_err()
        );
    }

    #[test]
    fn pool_executes_namespaced_tasks_and_survives_poison() {
        let params = ModelParams::default();
        let backend = Arc::new(Backend::native(params.clone()));
        let (tx, rx) = mpsc::channel();
        let pool = WorkerPool::new(
            &PoolConfig { workers: 1, ..Default::default() },
            params.clone(),
            backend,
            tx,
        )
        .unwrap();
        let ds = crate::workloads::build_small(Workload::Eaglet, &params, 3);
        let ns: Arc<str> = job_ns(9).into();
        crate::exec::cluster::stage_dataset(ds.as_ref(), &pool.dfs, &ns);
        let specs: Vec<TaskSpec> = pack(ds.metas(), TaskSizing::Tiniest)
            .into_iter()
            .map(|t| TaskSpec::new(t, Workload::Eaglet, 5))
            .collect();
        // poison the first task, run the rest
        for (i, spec) in specs.into_iter().enumerate() {
            pool.send(
                0,
                Down::Task(Box::new(TaskEnvelope {
                    job: 9,
                    attempt: 1,
                    ns: ns.clone(),
                    spec,
                    poison: i == 0,
                })),
            );
        }
        let mut done = 0;
        let mut failed = 0;
        while done + failed < 3 {
            match rx.recv().unwrap() {
                Up::Done { job: 9, attempt: 1, .. } => done += 1,
                // The worker's ack batcher may coalesce completions.
                Up::DoneBatch(items) => {
                    for it in &items {
                        assert_eq!((it.job, it.attempt), (9, 1));
                    }
                    done += items.len();
                }
                Up::TaskFailed { job: 9, attempt: 1, .. } => failed += 1,
                _ => panic!("unexpected pool message"),
            }
        }
        assert_eq!((done, failed), (2, 1), "poison must not kill the worker");
        assert_eq!(pool.spawned, 1);
        pool.shutdown();
        // Exited arrives with the executed count (poisoned task excluded).
        let exited = loop {
            match rx.recv().unwrap() {
                Up::Exited { executed, .. } => break executed,
                _ => continue,
            }
        };
        assert_eq!(exited, 2);
    }
}
