//! The persistent worker pool: map slots, prefetchers, and the shared
//! replicated store stay warm across jobs.
//!
//! This is the half of the thesis's "interactive subsampling" promise
//! the one-shot executor could not keep: `exec::run_cluster` pays
//! spawn/stage/join on every job, exactly the startup overhead Figs
//! 5–6 say must stay small. Pool workers are spawned once, serve tasks
//! from *any* job (each task carries its job id, attempt, and key
//! namespace), and exit only at service shutdown — the pool's
//! `spawned` count never grows past `workers`, which the serve tests
//! assert as the warm-pool invariant.
//!
//! Failure semantics differ from the solo executor on purpose: a task
//! error is reported as [`PoolUp::TaskFailed`] and the worker *keeps
//! running* — one tenant's bad job must not take map slots away from
//! the others. The dispatcher aborts and restarts just that job
//! (job-level recovery, scoped to the tenant).

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

use crate::cache::{AffinityIndex, CacheLayer};
use crate::data::ModelParams;
use crate::dfs::{job_ns, Dfs, LatencyModel, Prefetcher};
use crate::error::{Error, Result};
use crate::exec::cluster::{enqueue_keys, run_task, TaskDone};
use crate::exec::Backend;
use crate::metrics::Timer;
use crate::scheduler::TaskSpec;

/// Shape of the persistent pool backing a [`super::JobService`].
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Worker threads (map slots shared by every in-flight job).
    pub workers: usize,
    /// Data nodes backing the shared replicated store.
    pub data_nodes: usize,
    /// Replication factor for staged blocks (fixed for the pool's
    /// lifetime; the per-job adaptive controller is a solo-run feature).
    pub replication_factor: usize,
    pub latency: LatencyModel,
    /// Upper bound on each worker's prefetch depth k.
    pub prefetch_k: usize,
    /// Shared block cache budget in MiB (0 disables). The cache is
    /// keyed by content hash, so concurrent tenants staging identical
    /// sample blocks dedupe instead of double-fetching.
    pub cache_mb: usize,
    /// Cache-affinity dispatch across the warm pool.
    pub affinity: bool,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            workers: 4,
            data_nodes: 4,
            replication_factor: 2,
            latency: LatencyModel::none(),
            prefetch_k: 8,
            cache_mb: 0,
            affinity: false,
        }
    }
}

/// One task routed through the pool: a [`TaskSpec`] tagged with its
/// tenant. `ns` prefixes every block key; `attempt` lets the
/// dispatcher discard results that straggle in after a job restart.
pub(crate) struct PoolTask {
    pub(crate) job: u64,
    pub(crate) attempt: u32,
    pub(crate) ns: Arc<str>,
    pub(crate) spec: TaskSpec,
    /// Injected fault: the worker reports failure instead of running
    /// the task (recovery tests; modelled after `FailurePlan`).
    pub(crate) poison: bool,
}

/// Dispatcher → worker messages.
pub(crate) enum PoolMsg {
    Task(Box<PoolTask>),
    /// Drop every queued task of `job` with attempt ≤ `upto_attempt`
    /// and purge the job's namespace from the prefetcher. The worker
    /// acknowledges with [`PoolUp::Aborted`] so the dispatcher can
    /// reconcile its in-flight accounting.
    Abort { job: u64, upto_attempt: u32 },
    Shutdown,
}

/// Worker → dispatcher messages.
pub(crate) enum PoolUp {
    Done { job: u64, attempt: u32, done: TaskDone },
    TaskFailed { job: u64, attempt: u32, worker: usize, error: Error },
    Aborted { worker: usize, dropped: u64 },
    Exited { worker: usize, executed: u64 },
}

/// A spawned-once pool of workers over one shared store. `spawned`
/// equals `workers` for the pool's whole life — there is no respawn
/// path — and the serve report surfaces both so tests can assert the
/// "zero respawns between jobs" warm-pool invariant.
pub(crate) struct WorkerPool {
    pub(crate) dfs: Arc<Dfs>,
    pub(crate) workers: usize,
    pub(crate) spawned: usize,
    /// Shared affinity registry (None unless `PoolConfig::affinity`).
    pub(crate) affinity: Option<Arc<AffinityIndex>>,
    txs: Vec<mpsc::Sender<PoolMsg>>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn the pool. `up` is the dispatcher's channel; every worker
    /// reports completions, failures and its exit through it.
    pub(crate) fn new(
        cfg: &PoolConfig,
        params: ModelParams,
        backend: Arc<Backend>,
        up: mpsc::Sender<PoolUp>,
    ) -> Result<WorkerPool> {
        if cfg.workers == 0 {
            return Err(Error::Config("pool needs at least one worker".into()));
        }
        let dfs = Dfs::new(
            cfg.data_nodes.max(1),
            cfg.replication_factor.max(1),
            cfg.latency.clone(),
        );
        let layer = CacheLayer::build(&dfs, cfg.cache_mb, cfg.affinity);
        let mut txs = Vec::with_capacity(cfg.workers);
        let mut handles = Vec::with_capacity(cfg.workers);
        let mut spawned = 0;
        for w in 0..cfg.workers {
            let (tx, rx) = mpsc::channel::<PoolMsg>();
            txs.push(tx);
            let wcfg = PoolWorkerCfg {
                worker: w,
                prefetch_k: cfg.prefetch_k,
                affinity: layer.affinity.clone(),
            };
            let params = params.clone();
            let backend = backend.clone();
            let dfs = dfs.clone();
            let up = up.clone();
            handles.push(
                thread::Builder::new()
                    .name(format!("bts-serve-worker-{w}"))
                    .spawn(move || {
                        pool_worker_main(wcfg, params, backend, dfs, rx, up)
                    })
                    .map_err(|e| {
                        Error::Scheduler(format!("spawn pool worker {w}: {e}"))
                    })?,
            );
            spawned += 1;
        }
        Ok(WorkerPool {
            dfs,
            workers: cfg.workers,
            spawned,
            affinity: layer.affinity,
            txs,
            handles,
        })
    }

    /// Push a message to one worker. `false` means the worker's channel
    /// is gone (it exited — only possible after shutdown began).
    pub(crate) fn send(&self, worker: usize, msg: PoolMsg) -> bool {
        self.txs[worker].send(msg).is_ok()
    }

    /// Broadcast a job abort to every worker.
    pub(crate) fn abort(&self, job: u64, upto_attempt: u32) {
        for tx in &self.txs {
            let _ = tx.send(PoolMsg::Abort { job, upto_attempt });
        }
    }

    /// Tell every worker to exit and join them. The caller drains the
    /// up-channel for [`PoolUp::Exited`] accounting.
    pub(crate) fn shutdown(self) {
        for tx in &self.txs {
            let _ = tx.send(PoolMsg::Shutdown);
        }
        drop(self.txs);
        for h in self.handles {
            let _ = h.join();
        }
    }
}

/// Per-worker knobs handed to [`pool_worker_main`].
struct PoolWorkerCfg {
    worker: usize,
    prefetch_k: usize,
    affinity: Option<Arc<AffinityIndex>>,
}

/// One persistent pool worker: the same drain → wait → execute loop as
/// the solo executor's workers, but job-tagged, namespace-aware, and
/// immortal until `Shutdown` — task failures are reported and survived.
fn pool_worker_main(
    cfg: PoolWorkerCfg,
    params: ModelParams,
    backend: Arc<Backend>,
    dfs: Arc<Dfs>,
    rx: mpsc::Receiver<PoolMsg>,
    up: mpsc::Sender<PoolUp>,
) {
    let worker = cfg.worker;
    let mut pf = Prefetcher::new(dfs, cfg.prefetch_k);
    if let Some(index) = cfg.affinity {
        pf = pf.with_affinity(worker, index);
    }
    let mut queue: VecDeque<PoolTask> = VecDeque::new();
    let mut executed = 0u64;
    let handle_abort =
        |queue: &mut VecDeque<PoolTask>,
         pf: &mut Prefetcher,
         job: u64,
         upto: u32| {
            let before = queue.len();
            queue.retain(|t| !(t.job == job && t.attempt <= upto));
            let dropped = (before - queue.len()) as u64;
            // local-only: the job's staged blocks are unchanged across
            // attempts, so its shared-cache entries stay coherent (and
            // keep the restart warm); shared-structure invalidation
            // happens once, at retirement
            pf.purge_prefix_local(&job_ns(job));
            let _ = up.send(PoolUp::Aborted { worker, dropped });
        };
    'outer: loop {
        // Non-blocking drain: enqueue everything the dispatcher sent
        // (feeding the prefetcher lookahead across jobs).
        loop {
            match rx.try_recv() {
                Ok(PoolMsg::Task(t)) => {
                    enqueue_keys(&mut pf, &t.spec, &t.ns);
                    queue.push_back(*t);
                }
                Ok(PoolMsg::Abort { job, upto_attempt }) => {
                    handle_abort(&mut queue, &mut pf, job, upto_attempt);
                }
                Ok(PoolMsg::Shutdown) => break 'outer,
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    if queue.is_empty() {
                        break 'outer;
                    }
                    break;
                }
            }
        }
        // Idle: block for the next instruction, measuring queue wait.
        let mut queue_wait_s = 0.0;
        if queue.is_empty() {
            let wait_t = Timer::start();
            match rx.recv() {
                Ok(PoolMsg::Task(t)) => {
                    queue_wait_s = wait_t.secs();
                    enqueue_keys(&mut pf, &t.spec, &t.ns);
                    queue.push_back(*t);
                }
                Ok(PoolMsg::Abort { job, upto_attempt }) => {
                    handle_abort(&mut queue, &mut pf, job, upto_attempt);
                    continue;
                }
                Ok(PoolMsg::Shutdown) | Err(_) => break,
            }
        }
        let Some(task) = queue.pop_front() else { continue };
        if task.poison {
            let _ = up.send(PoolUp::TaskFailed {
                job: task.job,
                attempt: task.attempt,
                worker,
                error: Error::Scheduler(format!(
                    "injected task fault in job {} (attempt {}, task {})",
                    task.job, task.attempt, task.spec.task.seq
                )),
            });
            continue;
        }
        let (h0, m0) = (pf.hits, pf.misses);
        let (ch0, cm0) = (pf.cache_hits, pf.cache_misses);
        match run_task(&params, &backend, &mut pf, &task.spec, &task.ns) {
            Ok((partial, fetch_s, exec_s)) => {
                executed += 1;
                let done = TaskDone {
                    worker,
                    seq: task.spec.task.seq,
                    partial,
                    fetch_s,
                    exec_s,
                    queue_wait_s,
                    prefetch_hits: pf.hits - h0,
                    prefetch_misses: pf.misses - m0,
                    cache_hits: pf.cache_hits - ch0,
                    cache_misses: pf.cache_misses - cm0,
                };
                let sent = up.send(PoolUp::Done {
                    job: task.job,
                    attempt: task.attempt,
                    done,
                });
                if sent.is_err() {
                    break;
                }
            }
            Err(e) => {
                let _ = up.send(PoolUp::TaskFailed {
                    job: task.job,
                    attempt: task.attempt,
                    worker,
                    error: e,
                });
            }
        }
    }
    let _ = up.send(PoolUp::Exited { worker, executed });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Workload;
    use crate::kneepoint::{pack, TaskSizing};

    #[test]
    fn zero_worker_pool_is_a_config_error() {
        let (tx, _rx) = mpsc::channel();
        let backend = Arc::new(Backend::native(ModelParams::default()));
        let cfg = PoolConfig { workers: 0, ..Default::default() };
        assert!(
            WorkerPool::new(&cfg, ModelParams::default(), backend, tx)
                .is_err()
        );
    }

    #[test]
    fn pool_executes_namespaced_tasks_and_survives_poison() {
        let params = ModelParams::default();
        let backend = Arc::new(Backend::native(params.clone()));
        let (tx, rx) = mpsc::channel();
        let pool = WorkerPool::new(
            &PoolConfig { workers: 1, ..Default::default() },
            params.clone(),
            backend,
            tx,
        )
        .unwrap();
        let ds = crate::workloads::build_small(Workload::Eaglet, &params, 3);
        let ns: Arc<str> = job_ns(9).into();
        crate::exec::cluster::stage_dataset(ds.as_ref(), &pool.dfs, &ns);
        let specs: Vec<TaskSpec> = pack(ds.metas(), TaskSizing::Tiniest)
            .into_iter()
            .map(|t| TaskSpec::new(t, Workload::Eaglet, 5))
            .collect();
        // poison the first task, run the rest
        for (i, spec) in specs.into_iter().enumerate() {
            pool.send(
                0,
                PoolMsg::Task(Box::new(PoolTask {
                    job: 9,
                    attempt: 1,
                    ns: ns.clone(),
                    spec,
                    poison: i == 0,
                })),
            );
        }
        let mut done = 0;
        let mut failed = 0;
        for _ in 0..3 {
            match rx.recv().unwrap() {
                PoolUp::Done { job: 9, attempt: 1, .. } => done += 1,
                PoolUp::TaskFailed { job: 9, attempt: 1, .. } => failed += 1,
                _ => panic!("unexpected pool message"),
            }
        }
        assert_eq!((done, failed), (2, 1), "poison must not kill the worker");
        assert_eq!(pool.spawned, 1);
        pool.shutdown();
        // Exited arrives with the executed count (poisoned task excluded).
        let exited = loop {
            match rx.recv().unwrap() {
                PoolUp::Exited { executed, .. } => break executed,
                _ => continue,
            }
        };
        assert_eq!(exited, 2);
    }
}
