//! The long-lived job service: admission, multiplexed dispatch, and
//! per-job completion over one persistent pool.
//!
//! One dispatcher thread owns every in-flight job's [`JobCtx`] (the
//! per-job half of the `exec` leader) and interleaves their
//! [`crate::scheduler::TaskSpec`]s across the shared workers,
//! round-robin per map slot. Each job keeps its own
//! `TwoStepScheduler`, its own seed-derived task indices, and its own
//! seq-ordered reduce — which is the whole determinism argument: the
//! set of (seed, seq) pairs a job executes, and the order its partials
//! reduce in, are identical whether the job runs alone through
//! `run_cluster` or among twenty tenants here. Only *when* tasks run
//! changes; nothing about *what* they compute does.
//!
//! Failure isolation follows the same line: a failed task aborts and
//! restarts *its* job (same seed ⇒ same statistic), while every other
//! job's scheduler, partials, and staged blocks are untouched and the
//! pool keeps its workers.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use super::admission::{
    feasible, pop_index, AdmissionPolicy, InjectedFault, JobRequest,
    QueuedJob,
};
use super::pool::{PoolConfig, WorkerPool};
use crate::cache::{AffinityHook, CacheStats};
use crate::coordinator::JobOutput;
use crate::data::ModelParams;
use crate::dfs::job_ns;
use crate::error::{Error, Result};
use crate::exec::cluster::{stage_dataset, JobCtx};
use crate::exec::{Backend, ExecConfig};
use crate::kneepoint::pack;
use crate::membership::MemberEvent;
use crate::metrics::{JobReport, Timer};
use crate::net::protocol::ACCEPT_TIMEOUT;
use crate::runtime::Exec as _;
use crate::scheduler::{inflight_target, SchedConfig, TaskSpec};
use crate::slo::estimate_job_s;
use crate::transport::{Down, ReduceEnvelope, TaskEnvelope, Up};
use crate::util::json::{num, obj, s, Json};
use crate::util::stats::{summarize, Summary};
use crate::workloads::{build_small, default_compute_s_per_mib};

/// Service shape: the pool plus multiplexing and admission knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub pool: PoolConfig,
    /// Jobs multiplexed at once; further admitted jobs queue.
    pub max_active: usize,
    /// Dispatch window per worker, shared across jobs (the lookahead
    /// that keeps prefetchers pumping).
    pub inflight: usize,
    /// Per-job scheduler configuration.
    pub sched: SchedConfig,
    pub policy: AdmissionPolicy,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            pool: PoolConfig::default(),
            max_active: 4,
            inflight: 4,
            sched: SchedConfig::default(),
            policy: AdmissionPolicy::EdfWithRejection,
        }
    }
}

/// One finished job, as the submitting tenant sees it.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub id: u64,
    pub output: JobOutput,
    pub report: JobReport,
    /// Submission → promotion (admission queue wait).
    pub queue_wait_s: f64,
    /// Submission → first partial collected (interactivity signal).
    pub ttfp_s: f64,
    /// Submission → reduced statistic in hand.
    pub e2e_s: f64,
}

impl JobResult {
    /// One aligned per-job table row — shared by `bts serve` and the
    /// CI smoke example so the two surfaces can't drift.
    pub fn render_row(&self) -> String {
        format!(
            "job {:3} [{:10}] {:3} tasks  queue {:7.1}ms  \
             ttfp {:7.1}ms  e2e {:7.1}ms  restarts {}",
            self.id,
            self.report.workload,
            self.report.tasks,
            self.queue_wait_s * 1e3,
            self.ttfp_s * 1e3,
            self.e2e_s * 1e3,
            self.report.restarts,
        )
    }
}

/// Handle to an admitted job; `wait` blocks until the service reduces
/// it (or gives up on it).
pub struct JobHandle {
    pub id: u64,
    rx: mpsc::Receiver<Result<JobResult>>,
}

impl JobHandle {
    pub fn wait(self) -> Result<JobResult> {
        self.rx.recv().map_err(|_| {
            Error::Scheduler("service dropped the job".into())
        })?
    }

    /// Like [`JobHandle::wait`], but bounded: a dispatcher that has
    /// wedged fails the caller with a message after `timeout` instead
    /// of hanging it forever. Tests wait through this (with
    /// [`crate::util::testutil::SERVE_JOB_DEADLINE`]) so a regression
    /// surfaces as one failing assertion, not a stuck suite.
    pub fn wait_timeout(self, timeout: Duration) -> Result<JobResult> {
        match self.rx.recv_timeout(timeout) {
            Ok(r) => r,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                Err(Error::Scheduler(format!(
                    "job {} still unfinished after {timeout:?}",
                    self.id
                )))
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Err(Error::Scheduler("service dropped the job".into()))
            }
        }
    }

    /// Non-blocking poll: `None` while the job is still running. The
    /// federation front-door sweeps many outstanding handles on one
    /// thread, so it must never park on any single tenant's job.
    pub fn try_wait(&self) -> Option<Result<JobResult>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => Some(Err(
                Error::Scheduler("service dropped the job".into()),
            )),
        }
    }
}

/// Lock-free load digest a running service keeps current — the
/// federation front-door reads this to build its shard map without a
/// round trip through the dispatcher thread.
#[derive(Debug, Default)]
pub struct LoadGauge {
    active: AtomicU64,
    queued: AtomicU64,
    completed: AtomicU64,
}

impl LoadGauge {
    fn publish(&self, active: usize, queued: usize, completed: usize) {
        self.active.store(active as u64, Ordering::Relaxed);
        self.queued.store(queued as u64, Ordering::Relaxed);
        self.completed.store(completed as u64, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> LoadDigest {
        LoadDigest {
            active: self.active.load(Ordering::Relaxed) as usize,
            queued: self.queued.load(Ordering::Relaxed) as usize,
            completed: self.completed.load(Ordering::Relaxed),
        }
    }
}

/// One point-in-time reading of a [`LoadGauge`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct LoadDigest {
    /// Jobs currently multiplexed on the pool.
    pub active: usize,
    /// Admitted jobs waiting for a multiplex slot.
    pub queued: usize,
    /// Jobs completed since the service started.
    pub completed: u64,
}

/// Service-level metrics over a full serve session, in the same flat
/// JSON record family as `ExecResult::metrics_json`.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub jobs_admitted: usize,
    pub jobs_completed: usize,
    pub jobs_failed: usize,
    /// Rejections happen on the submitter's thread, before the
    /// dispatcher ever sees the job; `JobService::shutdown` fills this.
    pub jobs_rejected: u64,
    pub tasks_total: u64,
    /// First submission → last completion (service lifetime when no
    /// job completed).
    pub wall_s: f64,
    pub queue_wait: Summary,
    pub ttfp: Summary,
    pub e2e: Summary,
    pub workers: usize,
    /// Worker threads ever spawned; equal to `workers` iff the pool
    /// stayed warm (no respawns between jobs — there is no respawn
    /// path, and this stat proves it held).
    pub workers_spawned: usize,
    /// Tasks executed per worker over the whole session.
    pub worker_executed: Vec<u64>,
    /// Tasks cloned past the straggler threshold, summed over every
    /// completed job (speculative re-execution).
    pub speculated: u64,
    /// Speculated tasks whose clone beat the original.
    pub won_by_clone: u64,
    /// Intermediate bytes staged by executed shuffles, summed over
    /// every completed job (0 when no tenant asked for `reduce_tasks
    /// > 1`).
    pub shuffle_bytes: u64,
    pub dfs_bytes_served: u64,
    /// Payload bytes still resident in the shared replicated store at
    /// shutdown. Every retired job unstages its sample blocks and
    /// shuffle fragments, so a drained session ends at its pre-job
    /// footprint (0 for a fresh pool) — leaked `shuffle_key` entries
    /// show up here.
    pub dfs_stored_bytes: u64,
    /// Shared block-cache counters over the whole session, when the
    /// pool ran with `cache_mb > 0` (hit rate, cross-tenant dedup).
    pub cache: Option<CacheStats>,
    /// Wire frames written by the pool's link pumps over the session
    /// (zero for purely in-proc pools — mpsc is not a wire).
    pub frames_sent: u64,
    /// Control messages that crossed inside `TaskBatch`/`DoneBatch`
    /// frames (sum of batch lengths).
    pub frames_batched: u64,
    /// Total bytes written to worker links, headers included.
    pub wire_bytes: u64,
    /// `DfsBlock`/`DfsPut` payloads written vectored straight from
    /// their shared `Arc` — the copy-free block path.
    pub blocks_zero_copy: u64,
    /// Job ids in completion order (EDF tests read this).
    pub completed_order: Vec<u64>,
}

impl ServeReport {
    pub fn worker_respawns(&self) -> usize {
        self.workers_spawned.saturating_sub(self.workers)
    }

    /// Sustained service throughput in tasks per second.
    pub fn tasks_per_s(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.tasks_total as f64 / self.wall_s
        }
    }

    /// Flat JSON record for `results/BENCH_serve.json`.
    pub fn metrics_json(&self) -> Json {
        obj(vec![
            ("platform", s("bts-serve")),
            ("jobs_admitted", num(self.jobs_admitted as f64)),
            ("jobs_completed", num(self.jobs_completed as f64)),
            ("jobs_failed", num(self.jobs_failed as f64)),
            ("jobs_rejected", num(self.jobs_rejected as f64)),
            ("tasks_total", num(self.tasks_total as f64)),
            ("wall_s", num(self.wall_s)),
            ("tasks_per_s", num(self.tasks_per_s())),
            ("queue_wait_p50_s", num(self.queue_wait.p50)),
            ("queue_wait_p95_s", num(self.queue_wait.p95)),
            ("ttfp_p50_s", num(self.ttfp.p50)),
            ("ttfp_p95_s", num(self.ttfp.p95)),
            ("e2e_p50_s", num(self.e2e.p50)),
            ("e2e_p95_s", num(self.e2e.p95)),
            ("e2e_mean_s", num(self.e2e.mean)),
            ("workers", num(self.workers as f64)),
            ("workers_spawned", num(self.workers_spawned as f64)),
            ("worker_respawns", num(self.worker_respawns() as f64)),
            ("speculated", num(self.speculated as f64)),
            ("won_by_clone", num(self.won_by_clone as f64)),
            ("shuffle_bytes", num(self.shuffle_bytes as f64)),
            ("dfs_bytes_served", num(self.dfs_bytes_served as f64)),
            ("frames_sent", num(self.frames_sent as f64)),
            ("frames_batched", num(self.frames_batched as f64)),
            ("wire_bytes", num(self.wire_bytes as f64)),
            ("blocks_zero_copy", num(self.blocks_zero_copy as f64)),
            // disambiguates "cache off" from "cache on, zero hits" in
            // the cross-PR trajectory
            (
                "cache_enabled",
                num(if self.cache.is_some() { 1.0 } else { 0.0 }),
            ),
            (
                "cache_hit_rate",
                num(self.cache.as_ref().map_or(0.0, |c| c.hit_rate())),
            ),
            (
                "cache_dedup_hits",
                num(self
                    .cache
                    .as_ref()
                    .map_or(0.0, |c| c.dedup_hits as f64)),
            ),
            (
                "cache_evictions",
                num(self.cache.as_ref().map_or(0.0, |c| c.evicted as f64)),
            ),
            (
                "cache_resident_bytes",
                num(self
                    .cache
                    .as_ref()
                    .map_or(0.0, |c| c.resident_bytes as f64)),
            ),
        ])
    }

    pub fn render(&self) -> String {
        let cache = match &self.cache {
            Some(c) => format!(
                "; cache hits {:.0}% ({} dedup, {} evictions)",
                c.hit_rate() * 100.0,
                c.dedup_hits,
                c.evicted
            ),
            None => String::new(),
        };
        format!(
            "serve[{} workers, {} spawned] {} jobs in {:.2}s \
             ({} failed, {} rejected); {} tasks => {:.1} tasks/s; \
             queue wait p50 {:.1}ms p95 {:.1}ms; ttfp p50 {:.1}ms; \
             e2e p50 {:.1}ms p95 {:.1}ms; speculated {} (clone won {}); \
             shuffled {:.2} MB; dfs served {:.2} MB{}",
            self.workers,
            self.workers_spawned,
            self.jobs_completed,
            self.wall_s,
            self.jobs_failed,
            self.jobs_rejected,
            self.tasks_total,
            self.tasks_per_s(),
            self.queue_wait.p50 * 1e3,
            self.queue_wait.p95 * 1e3,
            self.ttfp.p50 * 1e3,
            self.e2e.p50 * 1e3,
            self.e2e.p95 * 1e3,
            self.speculated,
            self.won_by_clone,
            self.shuffle_bytes as f64 / 1048576.0,
            self.dfs_bytes_served as f64 / 1048576.0,
            cache,
        )
    }
}

/// Submitter → dispatcher commands.
enum Cmd {
    Submit(Box<Submission>),
    Drain,
}

struct Submission {
    id: u64,
    submitted: Instant,
    req: JobRequest,
    reply: mpsc::Sender<Result<JobResult>>,
}

/// A job the dispatcher has admitted but not yet promoted.
struct Pending {
    req: JobRequest,
    reply: mpsc::Sender<Result<JobResult>>,
}

/// One multiplexed in-flight job.
struct ActiveJob {
    id: u64,
    ctx: JobCtx,
    /// Retained for attempt restarts (blocks stay staged; only the
    /// scheduler and partials rebuild).
    specs: Vec<TaskSpec>,
    keys: Vec<String>,
    ns: Arc<str>,
    reply: mpsc::Sender<Result<JobResult>>,
    submitted: Instant,
    started: Instant,
    startup_s: f64,
    first_partial: Option<Instant>,
    attempt: u32,
    max_attempts: u32,
    fault: Option<InjectedFault>,
    /// Tasks dispatched in the current attempt (fault trigger point).
    dispatched: u64,
    cfg: ExecConfig,
    samples: usize,
    input_bytes: usize,
}

struct JobRecord {
    queue_wait_s: f64,
    ttfp_s: f64,
    e2e_s: f64,
}

/// The long-lived multi-tenant service. `start` spawns the pool and
/// the dispatcher; `submit` admits (or rejects) jobs from any thread;
/// `shutdown` drains and returns the session's [`ServeReport`].
pub struct JobService {
    submit_tx: mpsc::Sender<Cmd>,
    report_rx: mpsc::Receiver<ServeReport>,
    dispatcher: thread::JoinHandle<()>,
    next_id: AtomicU64,
    rejected: AtomicU64,
    workers: usize,
    policy: AdmissionPolicy,
    gauge: Arc<LoadGauge>,
}

impl JobService {
    pub fn start(
        backend: Arc<Backend>,
        cfg: ServeConfig,
    ) -> Result<JobService> {
        let params = backend.manifest().params.clone();
        let (up_tx, up_rx) = mpsc::channel();
        let pool =
            WorkerPool::new(&cfg.pool, params.clone(), backend.clone(), up_tx)?;
        let workers = pool.workers;
        let (submit_tx, submit_rx) = mpsc::channel();
        let (report_tx, report_rx) = mpsc::channel();
        let gauge = Arc::new(LoadGauge::default());
        let disp = Dispatcher {
            backend,
            params,
            pool,
            pool_rx: up_rx,
            submit_rx,
            gauge: gauge.clone(),
            policy: cfg.policy,
            max_active: cfg.max_active.max(1),
            target_inflight: cfg.inflight.max(1),
            sched_cfg: cfg.sched,
            queue: Vec::new(),
            active: Vec::new(),
            inflight: vec![0; workers],
            dead: vec![false; workers],
            rr: 0,
            clone_rr: 0,
            draining: false,
            jobs_admitted: 0,
            jobs_failed: 0,
            tasks_total: 0,
            speculated: 0,
            won_by_clone: 0,
            shuffle_bytes: 0,
            records: Vec::new(),
            completed_order: Vec::new(),
            first_submit: None,
            last_complete: None,
            epoch: Instant::now(),
            exited_executed: Vec::new(),
            starved_since: None,
        };
        let dispatcher = thread::Builder::new()
            .name("bts-serve-dispatcher".into())
            .spawn(move || disp.run(report_tx))
            .map_err(|e| {
                Error::Scheduler(format!("spawn dispatcher: {e}"))
            })?;
        Ok(JobService {
            submit_tx,
            report_rx,
            dispatcher,
            next_id: AtomicU64::new(1),
            rejected: AtomicU64::new(0),
            workers,
            policy: cfg.policy,
            gauge,
        })
    }

    /// Map slots this service's pool started with.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The dispatcher's current load digest (lock-free; at most one
    /// poll interval stale).
    pub fn load(&self) -> LoadDigest {
        self.gauge.snapshot()
    }

    /// The admission controller's time estimate for `req` on this
    /// service's pool (planner model seconds, not local wall-clock).
    pub fn estimate_s(&self, req: &JobRequest) -> f64 {
        estimate_job_s(
            req.workload,
            req.nominal_bytes(),
            self.workers,
            default_compute_s_per_mib(req.workload),
        )
    }

    /// Admit a job (returning a handle to wait on) or reject it at the
    /// door when its deadline is infeasible under the planner estimate.
    pub fn submit(&self, req: JobRequest) -> Result<JobHandle> {
        if req.samples == 0 {
            return Err(Error::Config("job needs at least one sample".into()));
        }
        if let Some(d) = req.deadline_s {
            // A NaN/infinite/negative deadline must die here, on the
            // submitter's thread — inside the dispatcher it would
            // panic Duration::from_secs_f64 and take down every
            // tenant's service.
            if !d.is_finite() || d < 0.0 {
                return Err(Error::Config(format!(
                    "deadline must be a finite non-negative number of \
                     seconds, got {d}"
                )));
            }
        }
        // Deadline-less requests are always feasible — don't pay the
        // planner simulation just to discard its answer.
        if self.policy == AdmissionPolicy::EdfWithRejection
            && req.deadline_s.is_some()
        {
            let est = self.estimate_s(&req);
            if !feasible(est, req.deadline_s) {
                self.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(Error::Admission(format!(
                    "planner estimates {est:.1}s for {} samples of {}, \
                     beyond the {:.3}s deadline",
                    req.samples,
                    req.workload.name(),
                    req.deadline_s.unwrap_or(f64::NAN),
                )));
            }
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (reply_tx, rx) = mpsc::channel();
        let sub = Submission {
            id,
            submitted: Instant::now(),
            req,
            reply: reply_tx,
        };
        self.submit_tx
            .send(Cmd::Submit(Box::new(sub)))
            .map_err(|_| Error::Scheduler("service is shut down".into()))?;
        Ok(JobHandle { id, rx })
    }

    /// Jobs rejected at admission so far.
    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Drain every queued and in-flight job, stop the pool, and return
    /// the session report.
    pub fn shutdown(self) -> Result<ServeReport> {
        self.submit_tx
            .send(Cmd::Drain)
            .map_err(|_| Error::Scheduler("dispatcher already gone".into()))?;
        let mut report = self.report_rx.recv().map_err(|_| {
            Error::Scheduler("dispatcher exited without a report".into())
        })?;
        report.jobs_rejected = self.rejected.load(Ordering::Relaxed);
        self.dispatcher
            .join()
            .map_err(|_| Error::Scheduler("dispatcher panicked".into()))?;
        Ok(report)
    }
}

struct Dispatcher {
    backend: Arc<Backend>,
    params: ModelParams,
    pool: WorkerPool,
    pool_rx: mpsc::Receiver<Up>,
    submit_rx: mpsc::Receiver<Cmd>,
    /// Load digest shared with [`JobService::load`] readers.
    gauge: Arc<LoadGauge>,
    policy: AdmissionPolicy,
    max_active: usize,
    target_inflight: usize,
    sched_cfg: SchedConfig,
    queue: Vec<QueuedJob<Pending>>,
    active: Vec<ActiveJob>,
    /// Tasks in flight per worker, across every job (dispatch window).
    inflight: Vec<usize>,
    /// Slots whose link died ([`Up::Lost`]); never dispatched to
    /// again. The warm pool has no respawn path — lost remote workers
    /// shrink the pool for the rest of the session.
    dead: Vec<bool>,
    /// Round-robin cursor over `active` (cross-job fairness).
    rr: usize,
    /// Separate rotating cursor for clone dispatch: `rr` only moves
    /// when regular tasks flow, which is exactly when clones don't —
    /// without its own cursor one tenant would get first pick of the
    /// scarce idle slots on every speculation tick.
    clone_rr: usize,
    draining: bool,
    jobs_admitted: usize,
    jobs_failed: usize,
    tasks_total: u64,
    /// Session-wide speculation counters (summed from finished jobs).
    speculated: u64,
    won_by_clone: u64,
    /// Session-wide shuffle bytes (summed from finished jobs).
    shuffle_bytes: u64,
    records: Vec<JobRecord>,
    completed_order: Vec<u64>,
    first_submit: Option<Instant>,
    last_complete: Option<Instant>,
    epoch: Instant,
    /// Lifetime task counts of workers that exited *before* shutdown
    /// (drained or lost); the post-loop drain only sees the survivors'
    /// `Up::Exited`.
    exited_executed: Vec<(usize, u64)>,
    /// When an elastic pool went all-dead with work still waiting; a
    /// rescuing joiner clears it, [`ACCEPT_TIMEOUT`] of starvation
    /// fails the tenants instead of hanging them forever.
    starved_since: Option<Instant>,
}

impl Dispatcher {
    fn run(mut self, report_tx: mpsc::Sender<ServeReport>) {
        loop {
            // 1. Pick up submissions (and the drain signal).
            loop {
                match self.submit_rx.try_recv() {
                    Ok(Cmd::Submit(sub)) => self.enqueue(*sub),
                    Ok(Cmd::Drain) => self.draining = true,
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        self.draining = true;
                        break;
                    }
                }
            }
            // 2. Promote queued jobs into free multiplex slots.
            let mut promoted = false;
            while self.active.len() < self.max_active && self.promote_one()
            {
                promoted = true;
            }
            if promoted {
                for w in 0..self.pool.workers {
                    self.top_up_worker(w);
                }
            }
            self.gauge.publish(
                self.active.len(),
                self.queue.len(),
                self.records.len(),
            );
            // 3. Drained and idle: stop.
            if self.draining
                && self.active.is_empty()
                && self.queue.is_empty()
            {
                break;
            }
            // 4. Idle service: nothing queued or running, so no pool
            //    traffic is coming — sleep on the submission channel
            //    instead of polling it. Stale pool acks (Aborted from
            //    a just-retired job) are drained first so in-flight
            //    accounting stays truthful.
            if self.active.is_empty() && self.queue.is_empty() {
                while let Ok(m) = self.pool_rx.try_recv() {
                    self.handle_up(m);
                }
                self.poll_membership();
                if self.pool.can_rejoin() {
                    // An elastic pool keeps its membership plane
                    // moving while idle: joiners between jobs must be
                    // admitted, not parked until the next submission.
                    match self
                        .submit_rx
                        .recv_timeout(Duration::from_millis(50))
                    {
                        Ok(Cmd::Submit(sub)) => self.enqueue(*sub),
                        Ok(Cmd::Drain) => self.draining = true,
                        Err(mpsc::RecvTimeoutError::Timeout) => {}
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            self.draining = true;
                        }
                    }
                } else {
                    match self.submit_rx.recv() {
                        Ok(Cmd::Submit(sub)) => self.enqueue(*sub),
                        Ok(Cmd::Drain) | Err(_) => self.draining = true,
                    }
                }
                continue;
            }
            // 5. Route pool messages (timeout keeps the submission
            //    poll responsive while jobs run — and doubles as the
            //    straggler-age check cadence).
            match self.pool_rx.recv_timeout(self.sched_cfg.straggler_poll())
            {
                Ok(m) => {
                    self.handle_up(m);
                    while let Ok(m) = self.pool_rx.try_recv() {
                        self.handle_up(m);
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
            // 6. Membership plane: admit joiners into fresh slots,
            //    route drain requests, and bound how long an all-dead
            //    elastic pool may starve its tenants.
            self.poll_membership();
            self.check_starvation();
            // 7. Speculative re-execution across every active tenant:
            //    overdue in-flight tasks are cloned to idle slots
            //    (first bit-identical result wins; dead clones are
            //    dropped on arrival).
            self.dispatch_clones();
        }
        // Orderly pool shutdown: every worker gets Shutdown, is joined,
        // and its lifetime task count is collected.
        let workers = self.pool.workers;
        let spawned = self.pool.spawned;
        let dfs_bytes_served = self.pool.dfs.bytes_served();
        let dfs_stored_bytes = self.pool.dfs.stored_bytes() as u64;
        let cache = self.pool.dfs.cache_stats();
        let wire = self.pool.net_totals();
        let pool = self.pool;
        pool.shutdown();
        let mut worker_executed = vec![0u64; workers];
        // Drained and lost workers exited before shutdown; their counts
        // were collected as the events arrived.
        for (w, n) in &self.exited_executed {
            if let Some(slot) = worker_executed.get_mut(*w) {
                *slot = *n;
            }
        }
        while let Ok(m) = self.pool_rx.try_recv() {
            if let Up::Exited { worker, executed, .. } = m {
                if let Some(slot) = worker_executed.get_mut(worker) {
                    *slot = executed;
                }
            }
        }
        let wall_s = match (self.first_submit, self.last_complete) {
            (Some(a), Some(b)) => b.duration_since(a).as_secs_f64(),
            _ => self.epoch.elapsed().as_secs_f64(),
        };
        let col = |f: fn(&JobRecord) -> f64| -> Summary {
            let v: Vec<f64> = self.records.iter().map(f).collect();
            summarize(if v.is_empty() { &[0.0] } else { &v })
        };
        let report = ServeReport {
            jobs_admitted: self.jobs_admitted,
            jobs_completed: self.records.len(),
            jobs_failed: self.jobs_failed,
            jobs_rejected: 0, // filled by JobService::shutdown
            tasks_total: self.tasks_total,
            wall_s,
            queue_wait: col(|r| r.queue_wait_s),
            ttfp: col(|r| r.ttfp_s),
            e2e: col(|r| r.e2e_s),
            workers,
            workers_spawned: spawned,
            worker_executed,
            speculated: self.speculated,
            won_by_clone: self.won_by_clone,
            shuffle_bytes: self.shuffle_bytes,
            dfs_bytes_served,
            dfs_stored_bytes,
            cache,
            frames_sent: wire.frames_sent,
            frames_batched: wire.frames_batched,
            wire_bytes: wire.wire_bytes,
            blocks_zero_copy: wire.blocks_zero_copy,
            completed_order: self.completed_order,
        };
        let _ = report_tx.send(report);
    }

    /// Clone overdue in-flight tasks of every active job onto idle
    /// live slots, round-robin across tenants so one job's stragglers
    /// cannot monopolize the pool's spare capacity.
    fn dispatch_clones(&mut self) {
        if !self.sched_cfg.speculate || self.active.is_empty() {
            return;
        }
        let workers = self.pool.workers;
        let mut idle: Vec<usize> = (0..workers)
            .filter(|&w| !self.dead[w] && self.inflight[w] == 0)
            .collect();
        if idle.is_empty() {
            return;
        }
        let n = self.active.len();
        let start = self.clone_rr % n;
        self.clone_rr = (start + 1) % n;
        for off in 0..n {
            if idle.is_empty() {
                return;
            }
            let i = (start + off) % n;
            let (jid, jattempt, ns) = {
                let a = &self.active[i];
                (a.id, a.attempt, a.ns.clone())
            };
            let clones = self.active[i].ctx.clone_candidates(&idle);
            for (w, spec) in clones {
                let env = TaskEnvelope {
                    job: jid,
                    attempt: jattempt,
                    ns: ns.clone(),
                    spec,
                    poison: false,
                };
                if self.pool.send(w, Down::Task(Box::new(env))) {
                    self.inflight[w] += 1;
                    idle.retain(|&x| x != w);
                } else {
                    self.on_worker_lost(w, "link closed mid-clone");
                    return;
                }
            }
            // Overdue reduce partitions speculate the same way.
            let rclones =
                self.active[i].ctx.reduce_clone_candidates(&idle);
            for (w, spec) in rclones {
                let partition = spec.partition;
                let env = ReduceEnvelope {
                    job: jid,
                    attempt: jattempt,
                    ns: ns.clone(),
                    spec,
                };
                if self.pool.send(w, Down::Reduce(Box::new(env))) {
                    self.inflight[w] += 1;
                    idle.retain(|&x| x != w);
                } else {
                    self.active[i].ctx.cancel_reduce_clone(partition);
                    self.on_worker_lost(w, "link closed mid-clone");
                    return;
                }
            }
        }
    }

    fn enqueue(&mut self, sub: Submission) {
        self.first_submit.get_or_insert(sub.submitted);
        self.jobs_admitted += 1;
        // submit() validated finiteness; the cap (~31 years) keeps
        // Instant + Duration from ever overflowing.
        let deadline_at = sub.req.deadline_s.map(|d| {
            sub.submitted + Duration::from_secs_f64(d.clamp(0.0, 1e9))
        });
        self.queue.push(QueuedJob {
            id: sub.id,
            submitted: sub.submitted,
            deadline_at,
            payload: Pending { req: sub.req, reply: sub.reply },
        });
    }

    fn all_dead(&self) -> bool {
        self.dead.iter().all(|&d| d)
    }

    /// One slot's link is gone — pump-reported [`Up::Lost`], or a
    /// failed send discovered it first (whichever wins the race; the
    /// loser is a no-op via the `dead` guard). Retire the slot, then
    /// restart every active job: any of them may have had tasks
    /// queued or running there, and a restart is harmless for the
    /// rest (same seeds ⇒ same statistics, tenant-scoped). Neighbour
    /// slots keep their workers. If no live slot remains, fail every
    /// active *and queued* job now — submitters must not block on a
    /// quiescent dead pool.
    fn on_worker_lost(&mut self, worker: usize, why: &str) {
        if self.dead[worker] {
            return;
        }
        self.dead[worker] = true;
        self.inflight[worker] = 0;
        if self.pool.elastic {
            // Elastic policy: the ledger knows which units the slot
            // solely carried — re-dispatch those, restart nothing.
            self.on_member_departed(worker);
            return;
        }
        let affected: Vec<(u64, u32)> =
            self.active.iter().map(|a| (a.id, a.attempt)).collect();
        for (job, attempt) in affected {
            self.on_task_failed(
                job,
                attempt,
                Error::Scheduler(format!(
                    "worker {worker} link lost: {why}"
                )),
            );
        }
        if self.all_dead() {
            self.fail_everything("every pool worker is lost");
        } else {
            // Restarted jobs re-dispatch immediately on the surviving
            // slots (their Dones would otherwise be the only refill
            // trigger).
            for w in 0..self.pool.workers {
                self.top_up_worker(w);
            }
        }
    }

    /// A slot left the membership — drained gracefully or lost — and
    /// the pool is elastic (or the departure was a drain). Instead of
    /// restarting every tenant, consult each tenant's checkpoint
    /// ledger (DESIGN.md §14): completed units are durable in the
    /// shared store, so only the units the departed slot was the sole
    /// carrier of re-dispatch on the survivors. A tenant whose
    /// stranded spec cannot be recovered falls back to its job-level
    /// restart, alone; its neighbours are untouched.
    fn on_member_departed(&mut self, worker: usize) {
        let affected: Vec<(u64, u32)> =
            self.active.iter().map(|a| (a.id, a.attempt)).collect();
        for (job, attempt) in affected {
            let Some(i) = self
                .active
                .iter()
                .position(|a| a.id == job && a.attempt == attempt)
            else {
                continue;
            };
            if let Err(e) = self.active[i].ctx.on_member_lost(worker) {
                self.on_task_failed(job, attempt, e);
            }
        }
        if self.all_dead() && !self.pool.can_rejoin() {
            self.fail_everything("every pool worker is lost");
        } else {
            // Re-queued units re-dispatch immediately on the
            // survivors (their Dones would otherwise be the only
            // refill trigger).
            for w in 0..self.pool.workers {
                self.top_up_worker(w);
            }
        }
    }

    /// Fail every active and queued job now — submitters must not
    /// block on a pool that cannot make progress.
    fn fail_everything(&mut self, why: &str) {
        while !self.active.is_empty() {
            let a = self.retire_active(0);
            let _ = a.reply.send(Err(Error::Scheduler(why.into())));
            self.jobs_failed += 1;
        }
        while let Some(qj) = self.queue.pop() {
            let _ =
                qj.payload.reply.send(Err(Error::Scheduler(why.into())));
            self.jobs_failed += 1;
        }
    }

    /// Drain the pool's membership events: a joiner becomes the next
    /// slot (pessimistic response-time prior, every active tenant's
    /// scheduler widened, dispatch window topped up) and a `bts drain`
    /// request becomes a [`Down::Drain`] to the slot — the worker's
    /// own `Up::Drained`, sent once its running task finishes, does
    /// the departure bookkeeping.
    fn poll_membership(&mut self) {
        while let Some(ev) = self.pool.try_member_event() {
            match ev {
                MemberEvent::Joined(link) => {
                    let w = self.pool.admit(link);
                    self.inflight.push(0);
                    self.dead.push(false);
                    self.starved_since = None;
                    self.pool.tracker.seed_pessimistic(w);
                    for a in &mut self.active {
                        a.ctx.add_worker();
                    }
                    self.top_up_worker(w);
                }
                MemberEvent::DrainRequested(w) => {
                    if w < self.dead.len() && !self.dead[w] {
                        let _ = self.pool.send(w, Down::Drain);
                    }
                }
            }
        }
    }

    /// Bound how long an all-dead elastic pool may starve its waiting
    /// tenants: a rescuing joiner clears the clock, [`ACCEPT_TIMEOUT`]
    /// without one fails the work instead of hanging it forever.
    /// (Static pools never get here — they fail everything the moment
    /// the last slot dies.)
    fn check_starvation(&mut self) {
        let starved = self.all_dead()
            && (!self.active.is_empty() || !self.queue.is_empty());
        if !starved {
            self.starved_since = None;
            return;
        }
        if !self.pool.can_rejoin() {
            return;
        }
        let since = *self.starved_since.get_or_insert_with(Instant::now);
        if since.elapsed() >= ACCEPT_TIMEOUT {
            self.fail_everything(
                "every worker left the membership and no replacement \
                 joined",
            );
            self.starved_since = None;
        }
    }

    /// Promote the next queued job (EDF or FIFO): build its dataset,
    /// stage its blocks under its namespace, and hand it a fresh
    /// [`JobCtx`]. Returns false when the queue is empty.
    fn promote_one(&mut self) -> bool {
        let Some(i) = pop_index(&self.queue, self.policy) else {
            return false;
        };
        let qj = self.queue.remove(i);
        let Pending { req, reply } = qj.payload;
        if self.all_dead() && !self.pool.can_rejoin() {
            // A dead pool that can never grow back cannot make
            // progress; fail fast instead of staging work that will
            // never run. (An elastic pool stages and waits for a
            // joiner, bounded by the starvation clock.)
            let _ = reply.send(Err(Error::Scheduler(
                "every pool worker is lost".into(),
            )));
            self.jobs_failed += 1;
            return true;
        }
        let started = Instant::now();
        let stage_t = Timer::start();
        let ds = build_small(req.workload, &self.params, req.samples);
        let tasks = pack(ds.metas(), req.sizing);
        let ns: Arc<str> = job_ns(qj.id).into();
        let (samples, input_bytes, keys) =
            stage_dataset(ds.as_ref(), &self.pool.dfs, &ns);
        let specs: Vec<TaskSpec> = tasks
            .into_iter()
            .map(|t| TaskSpec::new(t, req.workload, req.seed))
            .collect();
        let startup_s = stage_t.secs();
        let cfg = ExecConfig {
            sizing: req.sizing,
            workers: self.pool.workers,
            data_nodes: self.pool.dfs.nodes.len(),
            adaptive_rf: false, // the shared store's rf is pool policy
            sched: self.sched_cfg.clone(),
            seed: req.seed,
            attempt: 1,
            platform: "bts-serve".into(),
            reduce_tasks: req.reduce_tasks.max(1),
            partitioner: req.partitioner,
            // Elastic pools need every tenant's in-flight specs
            // retained so a departure can re-dispatch them.
            elastic: self.pool.elastic,
            ..ExecConfig::default()
        };
        let hook = self
            .pool
            .affinity
            .as_ref()
            .map(|a| AffinityHook::new(a.clone(), ns.clone()));
        // Dynamic mode: every tenant's JobCtx shares the pool-lifetime
        // tracker, so cross-job slot knowledge survives job churn.
        let tracker = self
            .sched_cfg
            .wants_tracker()
            .then(|| self.pool.tracker.clone());
        match JobCtx::new(
            specs.clone(),
            self.pool.dfs.clone(),
            cfg.clone(),
            self.pool.workers,
            samples,
            input_bytes,
            startup_s,
            hook,
            tracker,
            ns.clone(),
        ) {
            Ok(ctx) => {
                self.active.push(ActiveJob {
                    id: qj.id,
                    ctx,
                    specs,
                    keys,
                    ns,
                    reply,
                    submitted: qj.submitted,
                    started,
                    startup_s,
                    first_partial: None,
                    attempt: 1,
                    max_attempts: req.max_attempts.max(1),
                    fault: req.fault,
                    dispatched: 0,
                    cfg,
                    samples,
                    input_bytes,
                });
            }
            Err(e) => {
                // e.g. a dataset that packs to zero tasks
                for k in &keys {
                    self.pool.dfs.remove(k);
                }
                let _ = reply.send(Err(e));
                self.jobs_failed += 1;
            }
        }
        true
    }

    /// Fill `w`'s dispatch window, interleaving tasks from every
    /// active job round-robin — the cross-tenant multiplexing step.
    /// In dynamic mode the window collapses to one task for slots the
    /// pool tracker has watched straggle.
    fn top_up_worker(&mut self, w: usize) {
        let target = if self.sched_cfg.wants_tracker() {
            inflight_target(
                Some(self.pool.tracker.as_ref()),
                w,
                self.target_inflight,
            )
        } else {
            self.target_inflight
        };
        // Map claims accumulate into one burst — a `TaskBatch` frame
        // may interleave tenants, since every envelope carries its own
        // job id and namespace. Reduce dispatches flush the pending
        // burst first so per-link FIFO order is what a single-frame
        // dispatcher would have produced.
        let mut burst: Vec<TaskEnvelope> = Vec::new();
        while !self.dead[w] && self.inflight[w] + burst.len() < target {
            let n = self.active.len();
            if n == 0 {
                break;
            }
            let mut claimed = false;
            for off in 0..n {
                let i = (self.rr + off) % n;
                let job = &mut self.active[i];
                if let Some(spec) = job.ctx.next(w) {
                    let poison = job.fault.is_some_and(|f| {
                        f.applies_to(job.attempt)
                            && job.dispatched == f.after_tasks
                    });
                    job.dispatched += 1;
                    let (jid, jattempt) = (job.id, job.attempt);
                    burst.push(TaskEnvelope {
                        job: jid,
                        attempt: jattempt,
                        ns: job.ns.clone(),
                        spec,
                        poison,
                    });
                    self.rr = (i + 1) % n;
                    claimed = true;
                    break;
                }
                // Map scheduler dry for this job: claim a shuffled
                // reduce partition instead (present only once its last
                // map partial landed and `reduce_tasks > 1`).
                if let Some(rspec) = job.ctx.next_reduce(w) {
                    let env = ReduceEnvelope {
                        job: job.id,
                        attempt: job.attempt,
                        ns: job.ns.clone(),
                        spec: rspec,
                    };
                    self.rr = (i + 1) % n;
                    if !self.flush_burst(w, &mut burst) {
                        return;
                    }
                    if self.pool.send(w, Down::Reduce(Box::new(env))) {
                        self.inflight[w] += 1;
                        claimed = true;
                        break;
                    }
                    self.on_worker_lost(w, "link closed mid-dispatch");
                    return;
                }
            }
            if !claimed {
                break;
            }
        }
        let _ = self.flush_burst(w, &mut burst);
    }

    /// Send `w`'s collected map burst as one frame (a plain `Task` for
    /// a single claim, `TaskBatch` for more). Returns `false` when the
    /// link died — the claimed specs vanished with the frame, and the
    /// full lost-slot handling has already run: it restarts *every*
    /// affected tenant, so the pump's own `Up::Lost`, which may lose
    /// this race, can safely be a no-op.
    fn flush_burst(
        &mut self,
        w: usize,
        burst: &mut Vec<TaskEnvelope>,
    ) -> bool {
        if burst.is_empty() {
            return true;
        }
        let n = burst.len();
        let msg = if n == 1 {
            Down::Task(Box::new(burst.pop().expect("len checked")))
        } else {
            Down::TaskBatch(std::mem::take(burst))
        };
        if self.pool.send(w, msg) {
            self.inflight[w] += n;
            true
        } else {
            self.on_worker_lost(w, "link closed mid-dispatch");
            false
        }
    }

    fn handle_up(&mut self, msg: Up) {
        match msg {
            // A worker's ack batcher coalesced several completions
            // into one frame: unpack in order — batching changes the
            // wire, not the dispatcher's bookkeeping.
            Up::DoneBatch(items) => {
                for it in items {
                    self.handle_up(Up::Done {
                        job: it.job,
                        attempt: it.attempt,
                        done: Box::new(it.done),
                    });
                }
            }
            Up::Done { job, attempt, done } => {
                let w = done.worker;
                self.inflight[w] = self.inflight[w].saturating_sub(1);
                // Route to the job iff it's still on this attempt —
                // results that straggle in after a restart are stale.
                if let Some(i) = self
                    .active
                    .iter()
                    .position(|a| a.id == job && a.attempt == attempt)
                {
                    if self.active[i].first_partial.is_none() {
                        self.active[i].first_partial = Some(Instant::now());
                    }
                    self.active[i].ctx.on_done(*done);
                    // Last map partial in (and reduce_tasks > 1): the
                    // shuffle stages fragments and queues partitions.
                    let shuffled = match self.active[i]
                        .ctx
                        .maybe_start_shuffle(&self.params)
                    {
                        Ok(s) => s,
                        Err(e) => {
                            let (jid, jattempt) =
                                (self.active[i].id, self.active[i].attempt);
                            self.on_task_failed(jid, jattempt, e);
                            self.top_up_worker(w);
                            return;
                        }
                    };
                    if self.active[i].ctx.is_complete() {
                        self.finish_job(i);
                    } else if shuffled {
                        // Top every live slot up, not only `w`: idle
                        // slots have no Done of their own to wake them
                        // into the reduce phase.
                        for x in 0..self.pool.workers {
                            self.top_up_worker(x);
                        }
                    }
                }
                self.top_up_worker(w);
            }
            Up::ReduceDone { job, attempt, done } => {
                let w = done.worker;
                self.inflight[w] = self.inflight[w].saturating_sub(1);
                // Same staleness gate as map results: only the current
                // attempt's partitions count.
                if let Some(i) = self
                    .active
                    .iter()
                    .position(|a| a.id == job && a.attempt == attempt)
                {
                    self.active[i].ctx.on_reduce_done(*done);
                    if self.active[i].ctx.is_complete() {
                        self.finish_job(i);
                    }
                }
                self.top_up_worker(w);
            }
            Up::TaskFailed { job, attempt, worker, error } => {
                self.inflight[worker] =
                    self.inflight[worker].saturating_sub(1);
                self.on_task_failed(job, attempt, error);
                self.top_up_worker(worker);
            }
            Up::Aborted { worker, dropped } => {
                self.inflight[worker] = self.inflight[worker]
                    .saturating_sub(dropped as usize);
                self.top_up_worker(worker);
            }
            Up::Lost { worker, error } => {
                self.on_worker_lost(worker, &error.to_string());
            }
            Up::Drained { worker, returned: _ } => {
                // Graceful departure: the worker finished its running
                // task, handed back its queue, and is exiting. Same
                // membership bookkeeping as a loss — the ledger path
                // re-dispatches whatever it still solely carried (for
                // a static pool, the tenant-restart fallback runs).
                if worker < self.dead.len() && !self.dead[worker] {
                    self.dead[worker] = true;
                    self.inflight[worker] = 0;
                    self.on_member_departed(worker);
                }
            }
            // Workers exit at shutdown (collected by the post-loop
            // drain) or right after a drain/loss — record the early
            // ones' lifetime counts here so the session report keeps
            // them.
            Up::Exited { worker, executed, .. } => {
                self.exited_executed.push((worker, executed));
            }
        }
    }

    /// Remove job `i` from the active set, keep the round-robin cursor
    /// in range, and unstage the job's blocks from the shared store.
    fn retire_active(&mut self, i: usize) -> ActiveJob {
        let a = self.active.remove(i);
        self.rr = if self.active.is_empty() {
            0
        } else {
            self.rr % self.active.len()
        };
        for k in &a.keys {
            // also invalidates the shared block cache's key mappings
            // (the content stays resident as dedup fodder for later
            // identical tenants until the byte budget reclaims it)
            self.pool.dfs.remove(k);
        }
        // Shuffle fragments live in the same shared store under the
        // job's namespace; unstage them too (no-op keys are fine — a
        // job retired before its shuffle staged nothing).
        if a.cfg.reduce_tasks > 1 {
            for p in 0..a.cfg.reduce_tasks as u32 {
                for seq in 0..a.specs.len() {
                    self.pool
                        .dfs
                        .remove(&crate::reduce::shuffle_key(&a.ns, p, seq));
                }
            }
        }
        if let Some(aff) = &self.pool.affinity {
            aff.forget_prefix(&a.ns);
        }
        a
    }

    /// One task of `(job, attempt)` is lost (worker-reported failure or
    /// a dead worker channel): abort the attempt everywhere (workers
    /// purge the job's queued tasks and prefetched blocks), then
    /// restart the job on the warm pool or give up — neighbours
    /// unaffected either way.
    fn on_task_failed(&mut self, job: u64, attempt: u32, error: Error) {
        let Some(i) = self
            .active
            .iter()
            .position(|a| a.id == job && a.attempt == attempt)
        else {
            return; // stale attempt — already restarted or retired
        };
        self.pool.abort(job, attempt);
        // NB: the shared block cache and affinity registry are *not*
        // purged here — the job's blocks stay staged byte-identical
        // for the restart, so its cached entries are still coherent
        // and make the retry warm. Shared-structure invalidation
        // happens at retirement (`retire_active`), once.
        if self.active[i].attempt >= self.active[i].max_attempts {
            let a = self.retire_active(i);
            let _ = a.reply.send(Err(Error::JobFailed {
                attempts: a.attempt,
                cause: error.to_string(),
            }));
            self.jobs_failed += 1;
            return;
        }
        let workers = self.pool.workers;
        let dfs = self.pool.dfs.clone();
        // Blocks stay staged; same specs + seeds mean the restart
        // reproduces the statistic exactly.
        let (specs, cfg, samples, input_bytes, startup_s, ns) = {
            let a = &mut self.active[i];
            a.attempt += 1;
            a.dispatched = 0;
            a.first_partial = None;
            let mut cfg = a.cfg.clone();
            cfg.attempt = a.attempt;
            (
                a.specs.clone(),
                cfg,
                a.samples,
                a.input_bytes,
                a.startup_s,
                a.ns.clone(),
            )
        };
        let hook = self
            .pool
            .affinity
            .as_ref()
            .map(|a| AffinityHook::new(a.clone(), ns.clone()));
        let tracker = self
            .sched_cfg
            .wants_tracker()
            .then(|| self.pool.tracker.clone());
        match JobCtx::new(
            specs,
            dfs,
            cfg,
            workers,
            samples,
            input_bytes,
            startup_s,
            hook,
            tracker,
            ns,
        ) {
            Ok(ctx) => self.active[i].ctx = ctx,
            Err(e) => {
                let a = self.retire_active(i);
                let _ = a.reply.send(Err(e));
                self.jobs_failed += 1;
            }
        }
    }

    /// All partials in: seq-ordered reduce, unstage the job's blocks,
    /// answer the tenant.
    fn finish_job(&mut self, i: usize) {
        let a = self.retire_active(i);
        // A speculatively-completed job can leave dead copies queued
        // at (or executing on) pool slots — typically the slow slot
        // the clones just rescued it from. Abort them so the slot
        // doesn't burn its backlog fetching keys retire_active just
        // removed; the executing copy can't be stopped, but its stale
        // Done/TaskFailed is ignored (the job is no longer active).
        if self.sched_cfg.speculate {
            self.pool.abort(a.id, a.attempt);
        }
        match a.ctx.finish(self.backend.as_ref()) {
            Ok(fin) => {
                let e2e_s = a.submitted.elapsed().as_secs_f64();
                let queue_wait_s =
                    a.started.duration_since(a.submitted).as_secs_f64();
                let ttfp_s = a
                    .first_partial
                    .map(|t| t.duration_since(a.submitted).as_secs_f64())
                    .unwrap_or(e2e_s);
                self.tasks_total += fin.report.tasks as u64;
                self.speculated += fin.sched.speculated;
                self.won_by_clone += fin.sched.won_by_clone;
                self.shuffle_bytes += fin.report.shuffle_bytes;
                self.records.push(JobRecord { queue_wait_s, ttfp_s, e2e_s });
                self.completed_order.push(a.id);
                self.last_complete = Some(Instant::now());
                let _ = a.reply.send(Ok(JobResult {
                    id: a.id,
                    output: fin.output,
                    report: fin.report,
                    queue_wait_s,
                    ttfp_s,
                    e2e_s,
                }));
            }
            Err(e) => {
                self.jobs_failed += 1;
                let _ = a.reply.send(Err(e));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Workload;

    fn native_service(workers: usize, max_active: usize) -> JobService {
        let backend =
            Arc::new(Backend::native(ModelParams::default()));
        JobService::start(
            backend,
            ServeConfig {
                pool: PoolConfig { workers, ..Default::default() },
                max_active,
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn empty_session_reports_cleanly() {
        let svc = native_service(2, 2);
        let report = svc.shutdown().unwrap();
        assert_eq!(report.jobs_admitted, 0);
        assert_eq!(report.jobs_completed, 0);
        assert_eq!(report.workers_spawned, 2);
        assert_eq!(report.worker_respawns(), 0);
    }

    #[test]
    fn zero_sample_jobs_are_refused() {
        let svc = native_service(1, 1);
        let err = svc
            .submit(JobRequest::new(Workload::Eaglet, 0))
            .unwrap_err();
        assert!(matches!(err, Error::Config(_)));
        svc.shutdown().unwrap();
    }

    #[test]
    fn reduce_jobs_round_trip_bit_identical() {
        use crate::reduce::Partitioner;
        use crate::util::testutil::SERVE_JOB_DEADLINE;
        let run = |reduce_tasks: usize| -> JobOutput {
            let svc = native_service(3, 2);
            let h = svc
                .submit(
                    JobRequest::new(Workload::NetflixLo, 10)
                        .with_seed(11)
                        .with_reduce(reduce_tasks, Partitioner::Skew),
                )
                .unwrap();
            let r = h.wait_timeout(SERVE_JOB_DEADLINE).unwrap();
            assert_eq!(r.report.reduce_tasks, reduce_tasks.max(1));
            if reduce_tasks > 1 {
                assert!(r.report.shuffle_bytes > 0);
                assert!(r.report.shuffle_imbalance >= 1.0);
            } else {
                assert_eq!(r.report.shuffle_bytes, 0);
            }
            let report = svc.shutdown().unwrap();
            assert_eq!(report.jobs_completed, 1);
            assert_eq!(
                report.shuffle_bytes > 0,
                reduce_tasks > 1,
                "session shuffle bytes track the tenant's reduce mode"
            );
            r.output
        };
        // The multiplexed worker-pool reduce must be bit-identical to
        // the leader-side seq-ordered reduce.
        assert_eq!(run(1), run(4));
    }

    #[test]
    fn one_job_round_trips() {
        let svc = native_service(2, 2);
        let h = svc
            .submit(JobRequest::new(Workload::Eaglet, 8).with_seed(3))
            .unwrap();
        let r = h.wait().unwrap();
        assert!(matches!(r.output, JobOutput::Eaglet { .. }));
        assert_eq!(r.report.restarts, 0);
        assert!(r.e2e_s >= r.ttfp_s || r.report.tasks == 1);
        let report = svc.shutdown().unwrap();
        assert_eq!(report.jobs_completed, 1);
        assert!(report.tasks_total >= 1);
        // the record parses back as flat JSON with the percentiles
        let j = Json::parse(&report.metrics_json().to_string_pretty())
            .unwrap();
        assert!(j.req_f64("queue_wait_p50_s").is_ok());
        assert!(j.req_f64("e2e_p95_s").is_ok());
        assert!(j.req_f64("tasks_per_s").is_ok());
    }
}
