//! SLO-aware admission: what enters the service, and in what order.
//!
//! Subsample queries arrive as [`JobRequest`]s — workload, size, sizing
//! policy, and optionally a deadline. Admission is two decisions:
//!
//! 1. **Feasibility** (at submit): the `slo` planner's simulated time
//!    estimate for the request ([`crate::slo::estimate_job_s`]) is
//!    compared against the deadline; an estimate that already exceeds
//!    it is rejected immediately ([`crate::Error::Admission`]) instead
//!    of being queued to fail. The estimate is a *model* figure — the
//!    thesis-scale platform simulation, the same machinery behind
//!    `bts plan` / Fig 13 — so it orders and gates consistently even
//!    though local wall-clock differs.
//! 2. **Order** (at promote): [`AdmissionPolicy::EdfWithRejection`]
//!    pops the earliest absolute deadline first (deadline-less jobs
//!    queue FIFO behind every deadlined one);
//!    [`AdmissionPolicy::Fifo`] ignores deadlines entirely.

use std::time::Instant;

use crate::data::Workload;
use crate::kneepoint::TaskSizing;
use crate::reduce::Partitioner;

/// Per-sample size the admission estimator assumes, matching the
/// thesis-scale constants `sim::default_params` is calibrated with
/// (§4.1.1: a bi-polar family ≈ 576 KB, a Netflix movie ≈ 118 KB).
pub fn nominal_sample_bytes(workload: Workload) -> usize {
    let p = crate::data::ModelParams::default();
    match workload {
        Workload::Eaglet => 576 * 1024,
        Workload::NetflixHi | Workload::NetflixLo => 118 * 1024,
        // series workloads: one bare f32 series per sample
        Workload::SeqAddr => p.sa_len * 4,
        Workload::Ssag => p.ssag_len * 4,
    }
}

/// Fault injected into a multiplexed job (recovery tests): the
/// dispatcher poisons the task dispatched after `after_tasks` tasks of
/// the matching attempt, and the worker reports it failed instead of
/// running it. `on_attempt == 0` poisons every attempt (a persistent
/// fault that exhausts the job's recovery budget).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InjectedFault {
    pub on_attempt: u32,
    pub after_tasks: u64,
}

impl InjectedFault {
    /// Does this fault fire on `attempt`?
    pub fn applies_to(&self, attempt: u32) -> bool {
        self.on_attempt == 0 || self.on_attempt == attempt
    }
}

/// One tenant's job: what to compute, how to split it, and how soon
/// it is needed.
#[derive(Debug, Clone)]
pub struct JobRequest {
    pub workload: Workload,
    /// Dataset size in samples (families / movies); the service builds
    /// and stages the synthetic dataset itself, so a request is a few
    /// words — not a data shipment.
    pub samples: usize,
    pub sizing: TaskSizing,
    /// Job seed: per-task subsample indices derive from it, so the
    /// same request replays bit-identically (solo or multiplexed).
    pub seed: u64,
    /// Relative deadline in seconds from submission; `None` = best
    /// effort (FIFO behind every deadlined job under EDF).
    pub deadline_s: Option<f64>,
    /// Job-level recovery budget (attempts, ≥ 1).
    pub max_attempts: u32,
    pub fault: Option<InjectedFault>,
    /// Executed reduce partitions: 1 (default) keeps the leader-side
    /// seq-ordered reduce; >1 runs a shuffled worker-pool reduce phase.
    pub reduce_tasks: usize,
    /// Key → reduce-partition assignment policy (only consulted when
    /// `reduce_tasks > 1`).
    pub partitioner: Partitioner,
}

impl JobRequest {
    pub fn new(workload: Workload, samples: usize) -> JobRequest {
        JobRequest {
            workload,
            samples,
            sizing: TaskSizing::Kneepoint(64 * 1024),
            seed: 0xB75,
            deadline_s: None,
            max_attempts: 3,
            fault: None,
            reduce_tasks: 1,
            partitioner: Partitioner::Hash,
        }
    }

    pub fn with_seed(mut self, seed: u64) -> JobRequest {
        self.seed = seed;
        self
    }

    pub fn with_sizing(mut self, sizing: TaskSizing) -> JobRequest {
        self.sizing = sizing;
        self
    }

    pub fn with_deadline(mut self, deadline_s: f64) -> JobRequest {
        self.deadline_s = Some(deadline_s);
        self
    }

    /// Opt into the executed shuffle + reduce phase.
    pub fn with_reduce(
        mut self,
        reduce_tasks: usize,
        partitioner: Partitioner,
    ) -> JobRequest {
        self.reduce_tasks = reduce_tasks.max(1);
        self.partitioner = partitioner;
        self
    }

    /// Estimator input: nominal bytes this request's dataset stands
    /// for at thesis scale.
    pub fn nominal_bytes(&self) -> usize {
        self.samples * nominal_sample_bytes(self.workload)
    }
}

/// Queue-ordering policy for admitted jobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Earliest absolute deadline first; deadline-less jobs FIFO
    /// behind all deadlined ones; infeasible deadlines rejected at
    /// submit. The default.
    EdfWithRejection,
    /// Arrival order, deadlines ignored (no rejection).
    Fifo,
}

/// A job waiting for a map-slot share, with everything the dispatcher
/// needs to order it.
#[derive(Debug)]
pub(crate) struct QueuedJob<T> {
    pub(crate) id: u64,
    pub(crate) submitted: Instant,
    /// Absolute deadline (submission + relative deadline).
    pub(crate) deadline_at: Option<Instant>,
    pub(crate) payload: T,
}

/// Pick the index of the next job to promote under `policy`.
/// EDF: earliest `deadline_at`, `None` last, ties broken by id
/// (arrival order). FIFO: smallest id.
pub(crate) fn pop_index<T>(
    queue: &[QueuedJob<T>],
    policy: AdmissionPolicy,
) -> Option<usize> {
    if queue.is_empty() {
        return None;
    }
    let idx = match policy {
        AdmissionPolicy::Fifo => queue
            .iter()
            .enumerate()
            .min_by_key(|(_, q)| q.id)
            .map(|(i, _)| i)?,
        AdmissionPolicy::EdfWithRejection => queue
            .iter()
            .enumerate()
            .min_by_key(|(_, q)| {
                (q.deadline_at.is_none(), q.deadline_at, q.id)
            })
            .map(|(i, _)| i)?,
    };
    Some(idx)
}

/// The feasibility gate: can `estimate_s` of simulated work fit the
/// deadline at all? (`None` deadline is always feasible.)
pub fn feasible(estimate_s: f64, deadline_s: Option<f64>) -> bool {
    match deadline_s {
        Some(d) => estimate_s <= d,
        None => true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(id: u64, deadline_s: Option<f64>) -> QueuedJob<()> {
        let now = Instant::now();
        QueuedJob {
            id,
            submitted: now,
            deadline_at: deadline_s
                .map(|d| now + std::time::Duration::from_secs_f64(d)),
            payload: (),
        }
    }

    #[test]
    fn edf_pops_earliest_deadline_first() {
        let queue =
            vec![q(0, None), q(1, Some(500.0)), q(2, Some(100.0))];
        let i = pop_index(&queue, AdmissionPolicy::EdfWithRejection).unwrap();
        assert_eq!(queue[i].id, 2);
        // deadline-less jobs only go when no deadlined job waits
        let queue = vec![q(0, None), q(1, Some(1e6))];
        let i = pop_index(&queue, AdmissionPolicy::EdfWithRejection).unwrap();
        assert_eq!(queue[i].id, 1);
    }

    #[test]
    fn edf_breaks_ties_and_none_by_arrival() {
        let queue = vec![q(3, None), q(1, None), q(2, None)];
        let i = pop_index(&queue, AdmissionPolicy::EdfWithRejection).unwrap();
        assert_eq!(queue[i].id, 1);
    }

    #[test]
    fn fifo_ignores_deadlines() {
        let queue = vec![q(5, Some(1.0)), q(4, None)];
        let i = pop_index(&queue, AdmissionPolicy::Fifo).unwrap();
        assert_eq!(queue[i].id, 4);
        assert!(pop_index::<()>(&[], AdmissionPolicy::Fifo).is_none());
    }

    #[test]
    fn feasibility_gate() {
        assert!(feasible(10.0, None));
        assert!(feasible(10.0, Some(10.0)));
        assert!(!feasible(10.0, Some(9.99)));
    }

    #[test]
    fn fault_attempt_matching() {
        let once = InjectedFault { on_attempt: 2, after_tasks: 1 };
        assert!(!once.applies_to(1));
        assert!(once.applies_to(2));
        assert!(!once.applies_to(3));
        let every = InjectedFault { on_attempt: 0, after_tasks: 0 };
        assert!(every.applies_to(1) && every.applies_to(7));
    }

    #[test]
    fn request_defaults_are_sane() {
        let r = JobRequest::new(Workload::Eaglet, 40)
            .with_seed(7)
            .with_deadline(60.0);
        assert_eq!(r.seed, 7);
        assert_eq!(r.deadline_s, Some(60.0));
        assert!(r.max_attempts >= 1);
        assert_eq!(r.nominal_bytes(), 40 * 576 * 1024);
        assert_eq!(r.reduce_tasks, 1);
        assert_eq!(r.partitioner, Partitioner::Hash);
    }

    #[test]
    fn reduce_builder_clamps_and_sets() {
        let r = JobRequest::new(Workload::NetflixLo, 8)
            .with_reduce(0, Partitioner::Skew);
        assert_eq!(r.reduce_tasks, 1); // 0 clamps up to the r=1 path
        let r = r.with_reduce(4, Partitioner::Skew);
        assert_eq!(r.reduce_tasks, 4);
        assert_eq!(r.partitioner, Partitioner::Skew);
    }
}
