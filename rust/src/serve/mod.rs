//! The serve layer: a long-lived multi-tenant job service over the
//! exec spine (DESIGN.md §9).
//!
//! The thesis's premise is *interactive* subsampling — tiny tasks so
//! statistics come back in fractions of a second — yet a one-shot
//! `run_cluster` pays worker spawn, store staging, and join on every
//! job: exactly the startup overhead Figs 5–6 say must stay small.
//! This subsystem keeps the machinery warm and shares it:
//!
//! * [`pool`] — a persistent worker pool: map slots, prefetchers, and
//!   the replicated store outlive any job; tasks carry their job id
//!   and key namespace. Since the transport refactor the pool holds
//!   [`crate::transport::WorkerLink`]s — local threads and remote
//!   `bts worker --connect` processes are the same slots.
//! * [`admission`] — [`JobRequest`]s enter through an SLO-aware gate:
//!   the `slo` planner's time estimate rejects infeasible deadlines at
//!   the door, and the queue orders by earliest deadline first
//!   (deadline-less jobs ride FIFO behind).
//! * [`service`] — the dispatcher multiplexes every in-flight job's
//!   tasks across the shared workers while each job keeps its own
//!   scheduler, seeds, seq-ordered reduce, and recovery — so a
//!   multiplexed job's statistic is bit-identical to its solo run, and
//!   one tenant's failure restarts only that tenant's job.
//! * [`load`] — the sustained-load harness behind `bts serve`,
//!   `examples/serve_load.rs`, and `benches/serve_throughput.rs`
//!   (Poisson arrivals, mixed EAGLET/Netflix set, deliberate
//!   infeasible slice), writing `results/BENCH_serve.json`.

pub mod admission;
pub mod load;
pub mod pool;
pub mod service;

pub use admission::{
    feasible, nominal_sample_bytes, AdmissionPolicy, InjectedFault,
    JobRequest,
};
pub use load::{mixed_request, run_load, LoadConfig, LoadOutcome};
pub use pool::PoolConfig;
pub use service::{
    JobHandle, JobResult, JobService, LoadDigest, ServeConfig,
    ServeReport,
};
