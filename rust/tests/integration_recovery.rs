//! Job-level recovery: injected node failures restart the *whole job*
//! and reproduce the statistic bit-for-bit. Needs `make artifacts`.

use std::sync::Arc;

use bts::coordinator::{
    run_job, run_with_recovery, FailurePlan, JobConfig,
};
use bts::data::eaglet::{EagletConfig, EagletDataset};
use bts::error::Error;
use bts::kneepoint::TaskSizing;
use bts::runtime::Manifest;

fn manifest() -> Option<Arc<Manifest>> {
    match Manifest::load("artifacts") {
        Ok(m) => Some(Arc::new(m)),
        Err(_) => {
            eprintln!("skipping: run `make artifacts` first");
            None
        }
    }
}

fn dataset(m: &Manifest) -> EagletDataset {
    EagletDataset::generate(
        &m.params,
        EagletConfig { families: 30, ..Default::default() },
    )
}

fn cfg() -> JobConfig {
    JobConfig {
        sizing: TaskSizing::Tiniest,
        workers: 3,
        ..Default::default()
    }
}

#[test]
fn injected_failure_fails_a_single_attempt() {
    let Some(m) = manifest() else { return };
    let ds = dataset(&m);
    let mut c = cfg();
    c.failure = Some(FailurePlan { worker: 1, after_tasks: 3, on_attempt: 1 });
    let err = run_job(&ds, m.clone(), &c).unwrap_err();
    assert!(
        err.to_string().contains("injected node failure"),
        "unexpected error: {err}"
    );
}

#[test]
fn recovery_restarts_and_reproduces_the_clean_result() {
    let Some(m) = manifest() else { return };
    let ds = dataset(&m);

    // Clean run (no failure) is the reference answer.
    let clean = run_job(&ds, m.clone(), &cfg()).unwrap();

    // Same job with a transient failure on attempt 1.
    let mut c = cfg();
    c.failure = Some(FailurePlan { worker: 0, after_tasks: 2, on_attempt: 1 });
    let recovered = run_with_recovery(&ds, m.clone(), &c, 3).unwrap();

    assert_eq!(recovered.report.restarts, 1, "exactly one restart");
    assert_eq!(
        recovered.output, clean.output,
        "job-level recovery must reproduce the statistic exactly"
    );
}

#[test]
fn persistent_failure_exhausts_attempts() {
    let Some(m) = manifest() else { return };
    let ds = dataset(&m);
    let mut c = cfg();
    // on_attempt is checked per-attempt; make it fail on attempts 1 and 2
    // by running with max_attempts = 1 twice... instead simply inject on
    // attempt 1 with max_attempts = 1: the job must report JobFailed.
    c.failure = Some(FailurePlan { worker: 0, after_tasks: 1, on_attempt: 1 });
    let err = run_with_recovery(&ds, m.clone(), &c, 1).unwrap_err();
    match err {
        Error::JobFailed { attempts, cause } => {
            assert_eq!(attempts, 1);
            assert!(cause.contains("injected"));
        }
        other => panic!("expected JobFailed, got {other}"),
    }
}

#[test]
fn failure_on_later_attempt_still_recovers() {
    let Some(m) = manifest() else { return };
    let ds = dataset(&m);
    let clean = run_job(&ds, m.clone(), &cfg()).unwrap();
    let mut c = cfg();
    c.failure = Some(FailurePlan { worker: 2, after_tasks: 1, on_attempt: 2 });
    // attempt 1 runs clean → no restart at all
    let r = run_with_recovery(&ds, m.clone(), &c, 3).unwrap();
    assert_eq!(r.report.restarts, 0);
    assert_eq!(r.output, clean.output);
}
