//! Cross-module property tests: invariants that must hold for *any*
//! seed/shape, exercised with the crate's own deterministic generator
//! (`util::prop::check`).

use bts::data::eaglet::{EagletConfig, EagletDataset};
use bts::data::netflix::{NetflixConfig, NetflixDataset};
use bts::data::{Block, Dataset, ModelParams, SampleMeta, Workload};
use bts::dfs::{Dfs, LatencyModel, Ring};
use bts::kneepoint::{pack, smallest_kneepoint, CurvePoint, TaskSizing};
use bts::prop_assert;
use bts::scheduler::{SchedConfig, TaskSpec, TwoStepScheduler};
use bts::util::prop::check;
use bts::util::rng::Rng;
use std::sync::Arc;

#[test]
fn prop_block_encode_decode_identity() {
    check("block round trip", 200, |rng: &mut Rng| {
        let b = Block {
            id: bts::data::BlockId {
                kind: rng.below(2) as u32,
                sample: rng.next_u64(),
            },
            units: rng.range(1, 64) as u32,
            payload: (0..rng.below(2048) as usize)
                .map(|_| rng.f32() * 1e3 - 500.0)
                .collect(),
        };
        let back = Block::decode(&b.encode()).map_err(|e| e.to_string())?;
        prop_assert!(back == b, "round trip changed the block");
        Ok(())
    });
}

#[test]
fn prop_dataset_blocks_match_metas() {
    check("dataset meta/block agreement", 20, |rng: &mut Rng| {
        let p = ModelParams::default();
        let ds: Box<dyn Dataset> = if rng.below(2) == 0 {
            Box::new(EagletDataset::generate(
                &p,
                EagletConfig {
                    families: rng.range(3, 40) as usize,
                    seed: rng.next_u64(),
                    ..Default::default()
                },
            ))
        } else {
            Box::new(NetflixDataset::generate(
                &p,
                NetflixConfig {
                    movies: rng.range(3, 40) as usize,
                    seed: rng.next_u64(),
                    ..Default::default()
                },
            ))
        };
        for m in ds.metas() {
            let b = ds.encode_block(m.id);
            prop_assert!(
                b.payload.len() * 4 == m.bytes,
                "sample {}: block bytes {} != meta {}",
                m.id,
                b.payload.len() * 4,
                m.bytes
            );
            prop_assert!(b.units == m.units, "units mismatch");
        }
        Ok(())
    });
}

#[test]
fn prop_ring_replicas_distinct_and_stable() {
    check("ring replica invariants", 100, |rng: &mut Rng| {
        let nodes = rng.range(1, 24) as usize;
        let ring = Ring::new(nodes, 64);
        let rf = rng.range(1, nodes as u64 + 1) as usize;
        let key = format!("key-{}", rng.next_u64());
        let reps = ring.replicas(&key, rf);
        prop_assert!(reps.len() == rf.min(nodes), "replica count");
        let mut sorted = reps.clone();
        sorted.sort_unstable();
        sorted.dedup();
        prop_assert!(sorted.len() == reps.len(), "duplicate replicas");
        prop_assert!(
            reps.iter().all(|&n| n < nodes),
            "replica out of range"
        );
        // stability: same key, same ring → same replicas
        prop_assert!(ring.replicas(&key, rf) == reps, "not deterministic");
        Ok(())
    });
}

#[test]
fn prop_dfs_put_get_under_rf_changes() {
    check("dfs rf churn keeps data readable", 30, |rng: &mut Rng| {
        let nodes = rng.range(2, 9) as usize;
        let d = Dfs::new(nodes, 1, LatencyModel::none());
        let n_keys = rng.range(1, 40) as usize;
        for k in 0..n_keys {
            d.put(&format!("k{k}"), Arc::new(vec![k as u8; 64]));
        }
        for _ in 0..3 {
            let rf = rng.range(1, nodes as u64 + 1) as usize;
            d.set_replication_factor(rf);
            for k in 0..n_keys {
                let (data, _) =
                    d.get(&format!("k{k}")).map_err(|e| e.to_string())?;
                prop_assert!(data[0] == k as u8, "data corrupted");
            }
            // copies = keys × rf
            let copies: usize =
                d.nodes.iter().map(|n| n.block_count()).sum();
            prop_assert!(
                copies == n_keys * d.replication_factor(),
                "copies {} != {} × {}",
                copies,
                n_keys,
                d.replication_factor()
            );
        }
        Ok(())
    });
}

#[test]
fn prop_scheduler_with_random_report_patterns() {
    check("scheduler under adversarial timing", 40, |rng: &mut Rng| {
        let n = rng.range(1, 200) as usize;
        let workers = rng.range(1, 7) as usize;
        let metas: Vec<SampleMeta> = (0..n as u64)
            .map(|id| SampleMeta {
                id,
                bytes: rng.range(1, 50_000) as usize,
                units: rng.range(1, 8) as u32,
            })
            .collect();
        let specs: Vec<TaskSpec> =
            pack(&metas, TaskSizing::Kneepoint(rng.range(1_000, 100_000) as usize))
                .into_iter()
                .map(|t| TaskSpec::new(t, Workload::Eaglet, rng.next_u64()))
                .collect();
        let total = specs.len();
        let s = TwoStepScheduler::new(specs, workers, SchedConfig::default());
        let mut seen = std::collections::HashSet::new();
        // workers progress in random interleavings with random timings
        let mut live: Vec<usize> = (0..workers).collect();
        while !live.is_empty() {
            let w = live[rng.below(live.len() as u64) as usize];
            match s.next(w) {
                Some(t) => {
                    prop_assert!(
                        seen.insert(t.task.seq),
                        "double assignment of {}",
                        t.task.seq
                    );
                    s.report(w, rng.f64() * 0.01, rng.f64() * 0.1);
                }
                None => live.retain(|&x| x != w),
            }
        }
        prop_assert!(seen.len() == total, "{}/{total} ran", seen.len());
        Ok(())
    });
}

#[test]
fn prop_kneepoint_detector_sane() {
    check("kneepoint detector", 100, |rng: &mut Rng| {
        // synthesize a monotone curve with a known knee
        let knee_at = rng.range(2, 10) as usize;
        let n = rng.range(12, 20) as usize;
        let mut curve = Vec::new();
        let mut rate = 0.001;
        for i in 0..n {
            if i > knee_at {
                rate *= 1.5 + rng.f64(); // growth accelerates past knee
            }
            curve.push(CurvePoint {
                task_bytes: (i + 1) * 1024 * 1024,
                miss_rate: rate,
            });
            rate += 0.0001;
        }
        if let Some(k) = smallest_kneepoint(&curve, 0.8) {
            prop_assert!(
                k <= (knee_at + 2) * 1024 * 1024,
                "knee {} found after true knee {}",
                k,
                (knee_at + 1) << 20
            );
            prop_assert!(
                curve.iter().any(|p| p.task_bytes == k),
                "knee not a curve point"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_netflix_stats_finite_under_any_seed() {
    check("netflix generator stats", 20, |rng: &mut Rng| {
        let p = ModelParams::default();
        let ds = NetflixDataset::generate(
            &p,
            NetflixConfig {
                movies: 12,
                seed: rng.next_u64(),
                ..Default::default()
            },
        );
        for m in &ds.movies {
            prop_assert!(m.n_ratings >= 8, "too few ratings");
            for j in 0..p.ratings_cap {
                if m.mask[j] > 0.0 {
                    prop_assert!(
                        (1.0..=5.0).contains(&m.vals[j]),
                        "rating {} out of range",
                        m.vals[j]
                    );
                    prop_assert!(
                        (0.0..12.0).contains(&m.months[j]),
                        "month out of range"
                    );
                }
            }
        }
        Ok(())
    });
}

// ---- dynamic scheduler: tracker, placement score, quantile threshold ----

use bts::scheduler::dynamic::MIN_STRAGGLER_S;
use bts::scheduler::{placement_score, LatencyHistogram, ResponseTimeTracker};

#[test]
fn prop_placement_score_monotone_and_total() {
    check("placement score monotone", 200, |rng: &mut Rng| {
        let aff = rng.below(64) as usize;
        let p = rng.f64() * 10.0;
        let extra = rng.f64() * 10.0 + 1e-9;
        let fast = placement_score(aff, p);
        let slow = placement_score(aff, p + extra);
        prop_assert!(
            slow < fast,
            "slower prediction gained score: {slow} vs {fast}"
        );
        let held = placement_score(aff + 1, p);
        prop_assert!(
            held > fast,
            "an extra held block lowered the score: {held} vs {fast}"
        );
        // total on hostile inputs: never NaN, never poisoning a sort
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -1.0] {
            prop_assert!(
                placement_score(aff, bad).is_finite(),
                "non-finite score for predicted={bad}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_tracker_estimates_stay_finite_on_hostile_inputs() {
    check("tracker sanitizes inputs", 100, |rng: &mut Rng| {
        let t = ResponseTimeTracker::new();
        for _ in 0..rng.below(80) {
            let v = match rng.below(6) {
                0 => f64::NAN,
                1 => f64::INFINITY,
                2 => -1.0,
                3 => 0.0,
                4 => 1e300, // saturated but finite
                _ => rng.f64() * 0.1,
            };
            t.observe_task(rng.below(8) as usize, v);
            t.observe_rtt(rng.below(8) as usize, v);
        }
        for slot in 0..8 {
            let p = t.predicted_task_s(slot);
            prop_assert!(
                p.is_finite() && p >= 0.0,
                "slot {slot}: predicted {p} not a finite non-negative"
            );
            let r = t.relative_speed(slot);
            prop_assert!(
                r.is_finite() && r > 0.0 && r <= 1.0,
                "slot {slot}: relative speed {r} out of (0, 1]"
            );
        }
        // zero-sample and saturated cases both yield a sane threshold
        // (or none at all), never NaN and never below the floor
        if let Some(th) = t.straggler_threshold_s(rng.f64() * 100.0) {
            prop_assert!(
                th.is_finite() && th >= MIN_STRAGGLER_S,
                "threshold {th} below floor or non-finite"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_straggler_quantile_stable_across_permuted_observations() {
    check("quantile permutation stability", 100, |rng: &mut Rng| {
        let n = rng.range(1, 200) as usize;
        let xs: Vec<f64> = (0..n).map(|_| rng.f64() * 0.5).collect();
        let mut fwd = LatencyHistogram::new();
        for &x in &xs {
            fwd.observe(x);
        }
        // seeded Fisher–Yates: a genuinely different arrival order
        let mut perm = xs.clone();
        for i in (1..perm.len()).rev() {
            let j = rng.below(i as u64 + 1) as usize;
            perm.swap(i, j);
        }
        let mut shuf = LatencyHistogram::new();
        for &x in &perm {
            shuf.observe(x);
        }
        for pct in [10.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
            prop_assert!(
                fwd.quantile(pct) == shuf.quantile(pct),
                "quantile {pct} depends on arrival order"
            );
        }
        prop_assert!(
            fwd.quantile(99.0) >= fwd.quantile(50.0),
            "quantile not monotone in pct"
        );
        Ok(())
    });
}

// ---- reduce partitioner: total, deterministic, skew-resistant ----

use bts::coordinator::TaskPartial;
use bts::reduce::{build_plan, key_weights, Partitioner};

#[test]
fn prop_partition_plan_total_disjoint_deterministic() {
    check("partition plan covers the key space", 200, |rng: &mut Rng| {
        let n_keys = rng.range(1, 300) as usize;
        let partitions = rng.range(1, 17) as usize;
        let weights: Vec<f64> =
            (0..n_keys).map(|_| rng.pareto(1.5)).collect();
        for pt in [Partitioner::Hash, Partitioner::Skew] {
            let plan = build_plan(pt, &weights, partitions);
            // total: every key assigned, every assignment in range
            prop_assert!(
                plan.assign.len() == n_keys,
                "{}: {} assignments for {} keys",
                pt.name(),
                plan.assign.len(),
                n_keys
            );
            prop_assert!(
                plan.assign.iter().all(|&p| p < plan.partitions),
                "{}: assignment out of range",
                pt.name()
            );
            // disjoint cover: keys_of partitions the key space exactly
            let mut seen = vec![false; n_keys];
            for p in 0..plan.partitions {
                for k in plan.keys_of(p) {
                    prop_assert!(
                        !seen[k as usize],
                        "{}: key {k} owned by two partitions",
                        pt.name()
                    );
                    seen[k as usize] = true;
                    prop_assert!(
                        plan.partition_of(k) == p,
                        "{}: keys_of/partition_of disagree on {k}",
                        pt.name()
                    );
                }
            }
            prop_assert!(
                seen.iter().all(|&s| s),
                "{}: some key unowned",
                pt.name()
            );
            // deterministic: same inputs, same plan
            prop_assert!(
                build_plan(pt, &weights, partitions) == plan,
                "{}: plan not deterministic",
                pt.name()
            );
            prop_assert!(
                plan.imbalance_factor(&weights) >= 1.0 - 1e-9,
                "{}: imbalance below the balanced ideal",
                pt.name()
            );
        }
        Ok(())
    });
}

#[test]
fn prop_skew_partitioner_never_worse_than_hash() {
    check("skew imbalance <= hash under Zipf 1.5", 200, |rng: &mut Rng| {
        let n_keys = rng.range(2, 200) as usize;
        let partitions = rng.range(2, 13) as usize;
        // heavy-tailed key weights — the hot-key regime the skew
        // partitioner exists for
        let weights: Vec<f64> =
            (0..n_keys).map(|_| rng.pareto(1.5)).collect();
        let skew = build_plan(Partitioner::Skew, &weights, partitions)
            .imbalance_factor(&weights);
        let hash = build_plan(Partitioner::Hash, &weights, partitions)
            .imbalance_factor(&weights);
        prop_assert!(
            skew <= hash + 1e-12,
            "skew {skew} worse than hash {hash} on the same multiset"
        );
        Ok(())
    });
}

#[test]
fn prop_partition_plan_ignores_arrival_order() {
    check("plan invariant under arrival order", 50, |rng: &mut Rng| {
        let p = ModelParams::default();
        let n = rng.range(2, 12) as usize;
        // synthetic Netflix partials with skewed month traffic
        let partials: Vec<TaskPartial> = (0..n)
            .map(|_| {
                let mut stats =
                    vec![0.0f32; p.months * p.stat_fields];
                for m in 0..p.months {
                    let c = rng.pareto(1.5) as f32;
                    stats[m * p.stat_fields] = c * 3.5;
                    stats[m * p.stat_fields + 1] = c * 13.0;
                    stats[m * p.stat_fields + 2] = c;
                }
                TaskPartial::Netflix { stats }
            })
            .collect();
        // the executed path collects partials into seq-indexed slots,
        // so whatever order results *arrive* in, the weights (and the
        // plan) are computed from the same seq-ordered vector
        let mut slots: Vec<Option<TaskPartial>> = vec![None; n];
        let mut order: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = rng.below(i as u64 + 1) as usize;
            order.swap(i, j);
        }
        for &seq in &order {
            slots[seq] = Some(partials[seq].clone());
        }
        let collected: Vec<TaskPartial> =
            slots.into_iter().map(|s| s.unwrap()).collect();
        let w_seq =
            key_weights(Workload::NetflixLo, &p, &partials)
                .map_err(|e| e.to_string())?;
        let w_arr =
            key_weights(Workload::NetflixLo, &p, &collected)
                .map_err(|e| e.to_string())?;
        prop_assert!(w_seq == w_arr, "weights depend on arrival order");
        for pt in [Partitioner::Hash, Partitioner::Skew] {
            let a = build_plan(pt, &w_seq, 4);
            let b = build_plan(pt, &w_arr, 4);
            prop_assert!(
                a == b,
                "{}: assignment depends on arrival order",
                pt.name()
            );
        }
        Ok(())
    });
}

#[test]
fn prop_slower_observations_never_raise_a_slots_score() {
    check("slower slot never gains", 100, |rng: &mut Rng| {
        let t = ResponseTimeTracker::new();
        let base = rng.f64() * 0.01 + 1e-6;
        for _ in 0..10 {
            t.observe_task(0, base);
            t.observe_task(1, base);
        }
        let before = placement_score(0, t.predicted_task_s(1));
        // slot 1 turns strictly slower; its score must only fall
        for _ in 0..5 {
            t.observe_task(1, base * (2.0 + rng.f64() * 8.0));
        }
        let after = placement_score(0, t.predicted_task_s(1));
        prop_assert!(
            after < before,
            "slower slot gained placement score: {after} vs {before}"
        );
        Ok(())
    });
}

// ---- DRF fair allocation (federation front-door) --------------------

use bts::federation::{allocate, Capacity, Demand, TenantDemand};

/// Random federation capacity + tenant mix. Tenant names are distinct
/// by construction (the name is the allocator's tie-breaker).
fn random_drf_case(rng: &mut Rng) -> (Capacity, Vec<TenantDemand>) {
    let cap = Capacity {
        slots: rng.range(1, 64),
        cache_bytes: if rng.below(2) == 0 {
            0
        } else {
            rng.range(1, 1 << 20)
        },
    };
    let n = rng.range(1, 8) as usize;
    let tenants = (0..n)
        .map(|i| TenantDemand {
            tenant: format!("t{i:02}"),
            per_job: Demand {
                slots: rng.range(1, 5),
                cache_bytes: if cap.cache_bytes == 0 {
                    0
                } else {
                    rng.range(0, cap.cache_bytes / 2 + 1)
                },
            },
            jobs: rng.range(0, 12),
        })
        .collect();
    (cap, tenants)
}

/// `per_job` with the allocator's ≥1-slot normalization applied.
fn norm(d: Demand) -> Demand {
    Demand { slots: d.slots.max(1), cache_bytes: d.cache_bytes }
}

fn tenant_usage(t: &TenantDemand, granted: u64) -> Demand {
    let p = norm(t.per_job);
    Demand {
        slots: p.slots * granted,
        cache_bytes: p.cache_bytes * granted,
    }
}

#[test]
fn prop_drf_is_work_conserving_and_bounded() {
    check("drf work conservation", 300, |rng: &mut Rng| {
        let (cap, tenants) = random_drf_case(rng);
        let granted = allocate(cap, &tenants);
        let mut total = Demand::default();
        for (i, t) in tenants.iter().enumerate() {
            prop_assert!(
                granted[i] <= t.jobs,
                "tenant {} granted {} > requested {}",
                t.tenant,
                granted[i],
                t.jobs
            );
            total = total.plus(tenant_usage(t, granted[i]));
        }
        prop_assert!(
            cap.fits(total, Demand::default()),
            "allocation exceeds capacity: {total:?} vs {cap:?}"
        );
        // work conservation: any tenant left wanting must genuinely
        // not fit in the leftover capacity
        for (i, t) in tenants.iter().enumerate() {
            if granted[i] < t.jobs {
                prop_assert!(
                    !cap.fits(total, norm(t.per_job)),
                    "tenant {} starved with room to spare",
                    t.tenant
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_drf_envy_free_within_one_job_rounding() {
    check("drf envy-freeness", 300, |rng: &mut Rng| {
        let (cap, tenants) = random_drf_case(rng);
        let granted = allocate(cap, &tenants);
        for (a, ta) in tenants.iter().enumerate() {
            if granted[a] >= ta.jobs {
                continue; // satisfied tenants envy nobody
            }
            for (b, tb) in tenants.iter().enumerate() {
                if a == b {
                    continue;
                }
                // Only comparable pairs: whenever b's job fit the
                // leftover capacity, a's would have fit too — so a was
                // eligible at b's every grant.
                let na = norm(ta.per_job);
                let nb = norm(tb.per_job);
                let comparable = na.slots <= nb.slots
                    && (cap.cache_bytes == 0
                        || na.cache_bytes <= nb.cache_bytes);
                if !comparable {
                    continue;
                }
                let share_a =
                    cap.dominant_share(tenant_usage(ta, granted[a]));
                let share_b =
                    cap.dominant_share(tenant_usage(tb, granted[b]));
                let one_job_b = cap.dominant_share(nb);
                prop_assert!(
                    share_b <= share_a + one_job_b + 1e-9,
                    "{} (share {share_b}) envied by unmet {} \
                     (share {share_a}, b's increment {one_job_b})",
                    tb.tenant,
                    ta.tenant
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_drf_invariant_under_arrival_order() {
    check("drf permutation invariance", 300, |rng: &mut Rng| {
        let (cap, tenants) = random_drf_case(rng);
        let baseline: std::collections::HashMap<String, u64> = tenants
            .iter()
            .zip(allocate(cap, &tenants))
            .map(|(t, g)| (t.tenant.clone(), g))
            .collect();
        // Fisher–Yates over the same tenants: the *arrival order*
        // changes, nothing else
        let mut shuffled = tenants.clone();
        for i in (1..shuffled.len()).rev() {
            let j = rng.below(i as u64 + 1) as usize;
            shuffled.swap(i, j);
        }
        for (t, g) in shuffled.iter().zip(allocate(cap, &shuffled)) {
            prop_assert!(
                baseline[&t.tenant] == g,
                "tenant {} got {} after shuffle, {} before",
                t.tenant,
                g,
                baseline[&t.tenant]
            );
        }
        Ok(())
    });
}

/// Batching is a wire-shape optimization, never a semantic one: for
/// any (workload, sample count, seed, cache, speculation, transport)
/// shape, dispatching a refill window as one `TaskBatch` frame must
/// leave the `JobOutput` bit-identical to dispatching the same tasks
/// as singles.
#[test]
fn prop_task_batches_bit_identical_to_singles() {
    use bts::exec::{run_cluster, Backend, ExecConfig};
    use bts::net::run_worker;
    use bts::transport::{RemoteWorkerOpts, RemoteWorkers};
    use std::thread;

    check("batched == unbatched JobOutput", 6, |rng: &mut Rng| {
        let workload = if rng.below(2) == 0 {
            Workload::Eaglet
        } else {
            Workload::NetflixLo
        };
        let samples = rng.range(8, 24) as usize;
        let seed = rng.next_u64();
        let cache_mb = if rng.below(2) == 0 { 0 } else { 8 };
        let speculate = rng.below(2) == 0;
        let tcp = rng.below(2) == 0;
        let p = ModelParams::default();
        let ds = bts::workloads::build_small(workload, &p, samples);
        let backend = Arc::new(Backend::native(p.clone()));
        let mut outs = Vec::new();
        for batch in [true, false] {
            let base = ExecConfig {
                sizing: TaskSizing::Tiniest,
                seed,
                cache_mb,
                sched: SchedConfig {
                    dynamic: speculate,
                    speculate,
                    ..Default::default()
                },
                batch_dispatch: batch,
                ..Default::default()
            };
            let r = if tcp {
                let remote = RemoteWorkers::bind("127.0.0.1:0", 1)
                    .map_err(|e| e.to_string())?;
                let addr = remote.addr();
                let b2 = backend.clone();
                let h = thread::spawn(move || {
                    run_worker(&addr, b2, &RemoteWorkerOpts::default())
                });
                let r = run_cluster(
                    ds.as_ref(),
                    backend.clone(),
                    &ExecConfig {
                        workers: 1,
                        remote: Some(remote),
                        ..base
                    },
                )
                .map_err(|e| e.to_string())?;
                let _ = h.join();
                r
            } else {
                run_cluster(
                    ds.as_ref(),
                    backend.clone(),
                    &ExecConfig { workers: 2, ..base },
                )
                .map_err(|e| e.to_string())?
            };
            outs.push(r.output);
        }
        prop_assert!(
            outs[0] == outs[1],
            "batched != unbatched ({workload:?}, tcp={tcp}, \
             cache_mb={cache_mb}, speculate={speculate})"
        );
        Ok(())
    });
}

// ---- CLI grid-spec parsing (util::cli::Flags) -----------------------

use bts::util::cli::Flags;

/// Random item tokens a grid spec might carry (axis values, figure
/// ids, workload names).
fn grid_item(rng: &mut Rng) -> String {
    const POOL: &[&str] = &[
        "eaglet", "netflix_lo", "seqaddr", "ssag", "fig4", "tab1", "0",
        "8", "64", "on", "off", "hash", "skew", "tcp", "inproc",
    ];
    POOL[rng.below(POOL.len() as u64) as usize].to_string()
}

/// For any grouping of items into repeated `--only` occurrences — any
/// mix of `--flag v` / `--flag=v` spellings, any comma grouping —
/// `Flags::list` recovers exactly the flat item sequence, `get_all`
/// keeps every occurrence in order, and `get` returns the last one.
#[test]
fn prop_flags_repeated_and_comma_grouped_specs_round_trip() {
    check("grid-spec round trip", 300, |rng: &mut Rng| {
        let items: Vec<String> =
            (0..rng.range(1, 9)).map(|_| grid_item(rng)).collect();
        // split the item list into 1..=len contiguous occurrence groups
        let mut groups: Vec<Vec<String>> = vec![Vec::new()];
        for (i, it) in items.iter().enumerate() {
            if i > 0 && rng.below(2) == 0 {
                groups.push(Vec::new());
            }
            groups.last_mut().unwrap().push(it.clone());
        }
        let mut args: Vec<String> = Vec::new();
        for g in &groups {
            let joined = g.join(",");
            if rng.below(2) == 0 {
                args.push(format!("--only={joined}"));
            } else {
                args.push("--only".into());
                args.push(joined);
            }
        }
        let f = Flags::parse(&args, &["--only"])
            .map_err(|e| e.to_string())?;
        let flat = f.list("--only").map_err(|e| e.to_string())?;
        prop_assert!(
            flat == items,
            "list() lost or reordered items: {flat:?} != {items:?}"
        );
        let occs: Vec<&str> = f.get_all("--only").collect();
        let want: Vec<String> = groups.iter().map(|g| g.join(",")).collect();
        prop_assert!(
            occs == want.iter().map(String::as_str).collect::<Vec<_>>(),
            "get_all() changed occurrences: {occs:?} != {want:?}"
        );
        prop_assert!(
            f.get("--only") == Some(want.last().unwrap().as_str()),
            "get() is not the last occurrence"
        );
        Ok(())
    });
}

/// Corrupting any one occurrence of a valid grid spec with an empty
/// item — empty value, leading/trailing comma, or a doubled comma —
/// turns `Flags::list` into a clear error naming the flag, never a
/// silent skip.
#[test]
fn prop_flags_empty_list_items_are_clear_errors() {
    check("empty grid items rejected", 300, |rng: &mut Rng| {
        let n = rng.range(1, 5) as usize;
        let mut occs: Vec<String> = (0..n)
            .map(|_| {
                let k = rng.range(1, 4);
                (0..k)
                    .map(|_| grid_item(rng))
                    .collect::<Vec<_>>()
                    .join(",")
            })
            .collect();
        let victim = rng.below(n as u64) as usize;
        let good = occs[victim].clone();
        occs[victim] = match rng.below(4) {
            0 => String::new(),          // --only=
            1 => format!(",{good}"),     // leading comma
            2 => format!("{good},"),     // trailing comma
            _ => {
                // doubled comma inside (or degenerate lone comma)
                match good.split_once(',') {
                    Some((a, b)) => format!("{a},,{b}"),
                    None => format!("{good},,{good}"),
                }
            }
        };
        // the inline spelling is required for the empty-value case
        let args: Vec<String> =
            occs.iter().map(|o| format!("--only={o}")).collect();
        let f = Flags::parse(&args, &["--only"])
            .map_err(|e| e.to_string())?;
        let err = match f.list("--only") {
            Err(e) => e.to_string(),
            Ok(v) => {
                return Err(format!(
                    "empty item in {occs:?} parsed silently as {v:?}"
                ))
            }
        };
        prop_assert!(
            err.contains("--only"),
            "error must name the flag: {err}"
        );
        Ok(())
    });
}

/// Count/percentile knobs reject zero and negative values with errors
/// that name the flag and the offending value: `--cache-mb` is a
/// byte budget (unsigned — any negative literal is malformed), and
/// `--straggler-pct` / `--reduce-tasks`-style knobs sit behind
/// `num_at_least`, which errs exactly when the value is under the
/// bound.
#[test]
fn prop_flags_negative_or_zero_knob_values_are_clear_errors() {
    check("bad knob values rejected", 300, |rng: &mut Rng| {
        // negative --cache-mb can never parse as a byte budget
        let neg = -(rng.range(1, 1_000_000) as i64);
        let f = Flags::parse(
            &[format!("--cache-mb={neg}")],
            &["--cache-mb"],
        )
        .map_err(|e| e.to_string())?;
        let err = match f.num::<usize>("--cache-mb", 0) {
            Err(e) => e.to_string(),
            Ok(v) => {
                return Err(format!("--cache-mb {neg} parsed as {v}"))
            }
        };
        prop_assert!(
            err.contains("--cache-mb") && err.contains(&neg.to_string()),
            "error must name flag and value: {err}"
        );

        // num_at_least errs exactly on values under the bound, and
        // the error carries flag, value, and bound
        let v = rng.range(0, 201) as i64 - 100; // [-100, 100]
        let min = rng.range(1, 5) as i64;
        let f = Flags::parse(
            &[format!("--straggler-pct={v}")],
            &["--straggler-pct"],
        )
        .map_err(|e| e.to_string())?;
        match f.num_at_least("--straggler-pct", min, min) {
            Ok(got) => {
                prop_assert!(
                    v >= min && got == v,
                    "accepted {v} under bound {min}"
                );
            }
            Err(e) => {
                let msg = e.to_string();
                prop_assert!(
                    v < min,
                    "rejected in-range {v} (bound {min}): {msg}"
                );
                prop_assert!(
                    msg.contains("--straggler-pct")
                        && msg.contains(&v.to_string())
                        && msg.contains(&min.to_string()),
                    "error must name flag, value, bound: {msg}"
                );
            }
        }
        Ok(())
    });
}
