//! Executed shuffle + reduce oracle suite (DESIGN.md §13).
//!
//! The contract under test: for any reduce fan-out `r` and either
//! partitioner, the final [`JobOutput`] must be **bit-identical** to
//! the map-side-only aggregation the platform has always produced at
//! `r = 1` — across transports (in-proc vs loopback TCP), caches,
//! speculative re-execution, and a worker lost right at the shuffle
//! boundary. A second battery cross-validates the measured shuffle
//! against the Fig-16 analytical model (`sim::reduce_model`): network
//! demand must be zero at `r = 1` and non-decreasing in `r`, in both
//! the executed stage and the model.

use std::sync::Arc;
use std::thread;

use bts::coordinator::FailurePlan;
use bts::data::{ModelParams, Workload};
use bts::exec::{
    run_cluster, run_cluster_with_recovery, Backend, ExecConfig,
};
use bts::kneepoint::TaskSizing;
use bts::net::run_worker;
use bts::platforms::PlatformSpec;
use bts::reduce::Partitioner;
use bts::scheduler::SchedConfig;
use bts::sim::cluster::{Cluster, HardwareType};
use bts::sim::reduce_model::{sweep_reduce_tasks, ReduceParams};
use bts::transport::{RemoteWorkerOpts, RemoteWorkers};
use bts::workloads::build_small;

fn native() -> Arc<Backend> {
    Arc::new(Backend::native(ModelParams::default()))
}

fn params() -> ModelParams {
    ModelParams::default()
}

const SIZING: TaskSizing = TaskSizing::Kneepoint(16 * 1024);
const SEED: u64 = 0xB75;

fn cfg(workers: usize, r: usize, pt: Partitioner) -> ExecConfig {
    ExecConfig {
        sizing: SIZING,
        seed: SEED,
        workers,
        reduce_tasks: r,
        partitioner: pt,
        ..Default::default()
    }
}

/// Spawn `n` remote worker sessions against `addr`, each running the
/// full `bts worker` path on its own thread.
fn spawn_workers(
    addr: String,
    n: usize,
    opts: RemoteWorkerOpts,
) -> Vec<thread::JoinHandle<u64>> {
    (0..n)
        .map(|_| {
            let addr = addr.clone();
            let opts = opts.clone();
            let backend = native();
            thread::spawn(move || {
                run_worker(&addr, backend, &opts).expect("worker session")
            })
        })
        .collect()
}

#[test]
fn reduce_fanout_and_partitioner_never_change_the_statistic() {
    for workload in [
        Workload::Eaglet,
        Workload::NetflixLo,
        Workload::SeqAddr,
        Workload::Ssag,
    ] {
        let backend = native();
        let ds = build_small(workload, &params(), 36);

        // Oracle: map-side-only aggregation, the historical r=1 path.
        let reference = run_cluster(
            ds.as_ref(),
            backend.clone(),
            &cfg(3, 1, Partitioner::Hash),
        )
        .unwrap();
        assert_eq!(reference.report.reduce_tasks, 1);
        assert_eq!(
            reference.report.shuffle_bytes, 0,
            "r=1 must not shuffle"
        );

        for pt in [Partitioner::Hash, Partitioner::Skew] {
            for r in [2usize, 4] {
                let out = run_cluster(
                    ds.as_ref(),
                    backend.clone(),
                    &cfg(3, r, pt),
                )
                .unwrap();
                assert_eq!(
                    out.output,
                    reference.output,
                    "{workload:?} r={r} {} diverged from r=1",
                    pt.name()
                );
                assert_eq!(out.report.reduce_tasks, r);
                assert!(
                    out.report.shuffle_bytes > 0,
                    "executed shuffle must move bytes at r={r}"
                );
                assert!(out.report.shuffle_imbalance >= 1.0);
                assert_eq!(
                    out.report.reduce_turnaround.n, r,
                    "one turnaround sample per reduce partition"
                );
            }
        }
    }
}

#[test]
fn tcp_reduce_matches_inproc_bit_for_bit() {
    let backend = native();
    let ds = build_small(Workload::NetflixLo, &params(), 30);
    let reference = run_cluster(
        ds.as_ref(),
        backend.clone(),
        &cfg(3, 1, Partitioner::Hash),
    )
    .unwrap();

    let inproc = run_cluster(
        ds.as_ref(),
        backend.clone(),
        &cfg(3, 4, Partitioner::Skew),
    )
    .unwrap();

    // 1 local slot + 2 remote TCP workers fetching shuffle fragments
    // through the DFS proxy.
    let remote = RemoteWorkers::bind("127.0.0.1:0", 2).unwrap();
    let addr = remote.addr();
    let workers =
        spawn_workers(addr, 2, RemoteWorkerOpts::default());
    let tcp = run_cluster(
        ds.as_ref(),
        backend,
        &ExecConfig {
            remote: Some(remote),
            ..cfg(1, 4, Partitioner::Skew)
        },
    )
    .unwrap();
    for w in workers {
        w.join().unwrap();
    }

    assert_eq!(inproc.output, reference.output);
    assert_eq!(tcp.output, reference.output, "TCP reduce diverged");
    assert!(tcp.report.shuffle_bytes > 0);
    assert_eq!(
        tcp.report.shuffle_bytes, inproc.report.shuffle_bytes,
        "staged shuffle bytes must not depend on the transport"
    );
}

#[test]
fn caches_leave_reduce_bit_identical() {
    let backend = native();
    let ds = build_small(Workload::Eaglet, &params(), 30);
    let reference = run_cluster(
        ds.as_ref(),
        backend.clone(),
        &cfg(3, 1, Partitioner::Hash),
    )
    .unwrap();
    let cached = run_cluster(
        ds.as_ref(),
        backend,
        &ExecConfig { cache_mb: 16, ..cfg(3, 4, Partitioner::Skew) },
    )
    .unwrap();
    assert_eq!(cached.output, reference.output);
    assert!(cached.cache.is_some(), "cache stats should be reported");
}

#[test]
fn speculation_leaves_reduce_bit_identical() {
    let backend = native();
    let ds = build_small(Workload::NetflixLo, &params(), 30);
    let reference = run_cluster(
        ds.as_ref(),
        backend.clone(),
        &cfg(3, 1, Partitioner::Hash),
    )
    .unwrap();
    let spec = run_cluster(
        ds.as_ref(),
        backend,
        &ExecConfig {
            sched: SchedConfig {
                dynamic: true,
                speculate: true,
                straggler_pct: 95.0,
                ..Default::default()
            },
            ..cfg(3, 4, Partitioner::Skew)
        },
    )
    .unwrap();
    assert_eq!(
        spec.output, reference.output,
        "speculative reduce clones must not change the result"
    );
}

/// Worker 0 (the only slot) completes every map task, then dies before
/// it can execute any reduce partition — the leader has already staged
/// the shuffle, so the loss lands exactly at the map/reduce boundary.
/// Attempt 2 re-runs map + shuffle + reduce clean and must still match
/// the r=1 oracle bit for bit.
#[test]
fn worker_loss_at_the_shuffle_boundary_recovers_bit_identically() {
    let backend = native();
    let ds = build_small(Workload::NetflixLo, &params(), 24);
    let reference = run_cluster(
        ds.as_ref(),
        backend.clone(),
        &cfg(1, 1, Partitioner::Hash),
    )
    .unwrap();
    let map_tasks = reference.report.tasks as u64;

    let recovered = run_cluster_with_recovery(
        ds.as_ref(),
        backend,
        &ExecConfig {
            failure: Some(FailurePlan {
                worker: 0,
                after_tasks: map_tasks,
                on_attempt: 1,
            }),
            ..cfg(1, 4, Partitioner::Skew)
        },
        3,
    )
    .unwrap();
    assert_eq!(recovered.report.restarts, 1, "one lost attempt");
    assert_eq!(
        recovered.output, reference.output,
        "post-recovery reduce diverged from the oracle"
    );
    assert!(recovered.report.shuffle_bytes > 0);
}

/// Cross-validation against the Fig-16 analytical model: the executed
/// stage and `sim::reduce_model` must agree in *direction* — no
/// network demand at r=1, non-decreasing shuffle bytes in r — and the
/// skew partitioner must never report worse imbalance than hash on the
/// same job. (Wall-clock is not compared: the native backend is not
/// thesis-scale hardware; DESIGN.md §13 documents the calibration
/// gap.)
#[test]
fn measured_shuffle_trends_match_the_fig16_model() {
    let rs = [1usize, 2, 4];
    let cluster = Cluster::homogeneous(HardwareType::TypeII, 6);
    let platform = PlatformSpec::bts();

    for (workload, model) in [
        (Workload::Eaglet, ReduceParams::eaglet_like()),
        (Workload::NetflixLo, ReduceParams::netflix_like()),
    ] {
        let backend = native();
        let ds = build_small(workload, &params(), 30);

        let mut measured = Vec::new();
        let mut job_bytes = 0usize;
        for &r in &rs {
            let out = run_cluster(
                ds.as_ref(),
                backend.clone(),
                &cfg(3, r, Partitioner::Hash),
            )
            .unwrap();
            job_bytes = out.report.input_bytes;
            measured.push(out.report.shuffle_bytes);
        }
        assert_eq!(measured[0], 0, "{workload:?}: no shuffle at r=1");
        for w in measured.windows(2) {
            assert!(
                w[1] >= w[0],
                "{workload:?}: measured shuffle bytes must be \
                 non-decreasing in r: {measured:?}"
            );
        }

        let sweep = sweep_reduce_tasks(
            &model, job_bytes, &cluster, &platform, &rs,
        );
        for w in sweep.windows(2) {
            assert!(
                w[1].2 >= w[0].2,
                "model shuffle bytes must be non-decreasing in r"
            );
        }

        // Skew never reports worse imbalance than hash on the same job.
        let hash = run_cluster(
            ds.as_ref(),
            backend.clone(),
            &cfg(3, 4, Partitioner::Hash),
        )
        .unwrap();
        let skew = run_cluster(
            ds.as_ref(),
            backend,
            &cfg(3, 4, Partitioner::Skew),
        )
        .unwrap();
        assert!(
            skew.report.shuffle_imbalance
                <= hash.report.shuffle_imbalance + 1e-9,
            "{workload:?}: skew {} > hash {}",
            skew.report.shuffle_imbalance,
            hash.report.shuffle_imbalance
        );
        assert_eq!(hash.output, skew.output);
    }
}
