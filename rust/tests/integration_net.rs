//! Leader/worker over real TCP sockets (loopback), compared against the
//! in-process engine for agreement. Needs `make artifacts`.

use std::net::TcpListener;
use std::sync::Arc;

use bts::coordinator::{run_job, JobConfig, JobOutput};
use bts::data::eaglet::{EagletConfig, EagletDataset};
use bts::data::netflix::{NetflixConfig, NetflixDataset};
use bts::kneepoint::TaskSizing;
use bts::net::{run_worker, serve_job};
use bts::runtime::Manifest;

fn manifest() -> Option<Arc<Manifest>> {
    match Manifest::load("artifacts") {
        Ok(m) => Some(Arc::new(m)),
        Err(_) => {
            eprintln!("skipping: run `make artifacts` first");
            None
        }
    }
}

#[test]
fn tcp_job_matches_in_process_engine() {
    let Some(m) = manifest() else { return };
    let ds = EagletDataset::generate(
        &m.params,
        EagletConfig { families: 24, ..Default::default() },
    );
    let sizing = TaskSizing::Kneepoint(16 * 1024);
    let seed = 0xB75;

    // In-process reference (same sizing, same seed → same indices).
    let reference = run_job(
        &ds,
        m.clone(),
        &JobConfig { sizing, workers: 2, seed, ..Default::default() },
    )
    .unwrap();

    // Distributed run: leader + 2 worker threads over loopback TCP.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let report = std::thread::scope(|sc| {
        for w in 0..2u32 {
            let addr = addr.clone();
            let m = m.clone();
            sc.spawn(move || run_worker(&addr, w, m).unwrap());
        }
        serve_job(listener, &ds, m.clone(), sizing, 2, seed).unwrap()
    });

    assert_eq!(report.workers, 2);
    assert_eq!(report.tasks, reference.report.tasks);
    assert!(report.bytes_shipped >= ds.families.iter().map(|f| f.chunks as usize).sum::<usize>());
    assert_eq!(
        report.output, reference.output,
        "TCP path must produce the identical statistic"
    );
}

#[test]
fn tcp_netflix_job_completes() {
    let Some(m) = manifest() else { return };
    let ds = NetflixDataset::generate(
        &m.params,
        NetflixConfig { movies: 30, ..Default::default() },
    );
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let report = std::thread::scope(|sc| {
        sc.spawn({
            let addr = addr.clone();
            let m = m.clone();
            move || run_worker(&addr, 0, m).unwrap()
        });
        serve_job(listener, &ds, m.clone(), TaskSizing::Tiniest, 1, 1)
            .unwrap()
    });
    assert_eq!(report.tasks, 30);
    let JobOutput::Netflix(stats) = report.output else {
        panic!("wrong kind")
    };
    assert!(stats.count.iter().sum::<f64>() > 0.0);
}

#[test]
fn worker_counts_tasks_and_exits_on_done() {
    let Some(m) = manifest() else { return };
    let ds = EagletDataset::generate(
        &m.params,
        EagletConfig { families: 10, ..Default::default() },
    );
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let (tasks_done, report) = std::thread::scope(|sc| {
        let h = sc.spawn({
            let addr = addr.clone();
            let m = m.clone();
            move || run_worker(&addr, 0, m).unwrap()
        });
        let report =
            serve_job(listener, &ds, m.clone(), TaskSizing::Tiniest, 1, 7)
                .unwrap();
        (h.join().unwrap(), report)
    });
    assert_eq!(tasks_done, 10);
    assert_eq!(report.tasks, 10);
}
