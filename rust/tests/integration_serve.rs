//! The serve layer end to end against the native backend: multi-tenant
//! multiplexing with per-job determinism, warm-pool reuse, SLO-aware
//! admission, and tenant-scoped recovery. No artifacts needed.
//!
//! Every job wait is bounded by the shared
//! [`bts::util::testutil::SERVE_JOB_DEADLINE`] (the same constant the
//! serve bench uses), so a wedged dispatcher fails one assertion fast
//! instead of hanging the whole suite.

use std::sync::Arc;

use bts::data::{ModelParams, Workload};
use bts::error::Error;
use bts::exec::{run_cluster, Backend, ExecConfig};
use bts::kneepoint::TaskSizing;
use bts::serve::{
    AdmissionPolicy, InjectedFault, JobRequest, JobService, PoolConfig,
    ServeConfig,
};
use bts::util::testutil::SERVE_JOB_DEADLINE;
use bts::workloads::build_small;

fn native() -> Arc<Backend> {
    Arc::new(Backend::native(ModelParams::default()))
}

fn service(workers: usize, max_active: usize) -> JobService {
    JobService::start(
        native(),
        ServeConfig {
            pool: PoolConfig { workers, ..Default::default() },
            max_active,
            ..Default::default()
        },
    )
    .unwrap()
}

/// Run `req` solo through the one-shot executor — the oracle every
/// multiplexed job must match bit for bit.
fn solo_output(req: &JobRequest) -> bts::coordinator::JobOutput {
    let backend = native();
    let ds = build_small(req.workload, &ModelParams::default(), req.samples);
    let cfg = ExecConfig {
        sizing: req.sizing,
        seed: req.seed,
        ..Default::default()
    };
    run_cluster(ds.as_ref(), backend, &cfg).unwrap().output
}

fn mixed(i: usize, samples: usize) -> JobRequest {
    let workload = match i % 3 {
        0 => Workload::Eaglet,
        1 => Workload::NetflixHi,
        _ => Workload::NetflixLo,
    };
    JobRequest::new(workload, samples)
        .with_seed(0xA11CE ^ (i as u64))
        .with_sizing(TaskSizing::Kneepoint(16 * 1024))
}

#[test]
fn multiplexed_jobs_match_their_solo_runs_bit_for_bit() {
    let svc = service(4, 3);
    let reqs: Vec<JobRequest> = (0..6).map(|i| mixed(i, 24)).collect();
    let handles: Vec<_> = reqs
        .iter()
        .map(|r| svc.submit(r.clone()).unwrap())
        .collect();
    // all six run interleaved over the shared pool (3 at a time)
    let results: Vec<_> = handles
        .into_iter()
        .map(|h| h.wait_timeout(SERVE_JOB_DEADLINE).unwrap())
        .collect();
    for (req, res) in reqs.iter().zip(&results) {
        assert_eq!(
            res.output,
            solo_output(req),
            "job {} ({}) diverged from its solo run",
            res.id,
            req.workload.name()
        );
        assert_eq!(res.report.restarts, 0);
        assert!(res.e2e_s > 0.0);
    }
    let report = svc.shutdown().unwrap();
    assert_eq!(report.jobs_completed, 6);
    assert_eq!(report.jobs_failed, 0);
}

#[test]
fn twenty_mixed_jobs_reuse_one_warm_pool() {
    let workers = 4;
    let svc = service(workers, 4);
    let handles: Vec<_> = (0..20)
        .map(|i| svc.submit(mixed(i, 16)).unwrap())
        .collect();
    for h in handles {
        h.wait_timeout(SERVE_JOB_DEADLINE).unwrap();
    }
    let report = svc.shutdown().unwrap();
    assert_eq!(report.jobs_completed, 20);
    assert_eq!(report.jobs_failed, 0);
    // the warm-pool invariant: one spawn per worker for the whole
    // session, no respawns between jobs, and those same workers
    // executed every task of every job
    assert_eq!(report.workers_spawned, workers);
    assert_eq!(report.worker_respawns(), 0);
    assert_eq!(report.worker_executed.len(), workers);
    let executed: u64 = report.worker_executed.iter().sum();
    assert_eq!(executed, report.tasks_total);
    assert!(report.tasks_total >= 20, "each job runs at least one task");
    assert!(report.wall_s > 0.0 && report.tasks_per_s() > 0.0);
    // latency accounting covered every job
    assert_eq!(report.queue_wait.n, 20);
    assert_eq!(report.e2e.n, 20);
    assert_eq!(report.completed_order.len(), 20);
}

#[test]
fn infeasible_deadlines_are_rejected_at_admission() {
    let svc = service(2, 2);
    // no simulated configuration finishes in a microsecond
    let err = svc
        .submit(mixed(0, 40).with_deadline(1e-6))
        .unwrap_err();
    assert!(
        matches!(err, Error::Admission(_)),
        "expected Admission error, got {err}"
    );
    assert_eq!(svc.rejected(), 1);
    // non-finite / negative deadlines are config errors on the
    // submitter's thread, not dispatcher panics (and don't count as
    // admission rejections)
    for bad in [f64::INFINITY, f64::NAN, -1.0] {
        let err = svc.submit(mixed(0, 8).with_deadline(bad)).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "deadline {bad}: {err}");
    }
    assert_eq!(svc.rejected(), 1);
    // a generous deadline passes the same gate and completes
    let h = svc.submit(mixed(0, 12).with_deadline(1e6)).unwrap();
    let r = h.wait_timeout(SERVE_JOB_DEADLINE).unwrap();
    assert_eq!(r.report.restarts, 0);
    let report = svc.shutdown().unwrap();
    assert_eq!(report.jobs_rejected, 1);
    assert_eq!(report.jobs_completed, 1);
}

#[test]
fn fifo_policy_never_rejects() {
    let svc = JobService::start(
        native(),
        ServeConfig {
            pool: PoolConfig { workers: 2, ..Default::default() },
            max_active: 2,
            policy: AdmissionPolicy::Fifo,
            ..Default::default()
        },
    )
    .unwrap();
    // under FIFO the same impossible deadline is admitted (and simply
    // missed) rather than rejected
    let h = svc.submit(mixed(0, 8).with_deadline(1e-6)).unwrap();
    h.wait_timeout(SERVE_JOB_DEADLINE).unwrap();
    let report = svc.shutdown().unwrap();
    assert_eq!(report.jobs_rejected, 0);
    assert_eq!(report.jobs_completed, 1);
}

#[test]
fn edf_promotes_urgent_jobs_first() {
    // One multiplex slot: job A occupies it while B (loose deadline)
    // and C (tight deadline) queue; EDF must complete C before B.
    let svc = service(2, 1);
    let a = svc.submit(mixed(0, 40).with_seed(1)).unwrap();
    let b = svc
        .submit(mixed(1, 12).with_seed(2).with_deadline(9_000.0))
        .unwrap();
    let c = svc
        .submit(mixed(2, 12).with_seed(3).with_deadline(3_600.0))
        .unwrap();
    let (b_id, c_id) = (b.id, c.id);
    a.wait_timeout(SERVE_JOB_DEADLINE).unwrap();
    b.wait_timeout(SERVE_JOB_DEADLINE).unwrap();
    c.wait_timeout(SERVE_JOB_DEADLINE).unwrap();
    let report = svc.shutdown().unwrap();
    let pos = |id: u64| {
        report
            .completed_order
            .iter()
            .position(|&x| x == id)
            .unwrap()
    };
    assert!(
        pos(c_id) < pos(b_id),
        "EDF must finish the tight deadline first: order {:?}",
        report.completed_order
    );
}

#[test]
fn one_tenant_recovers_without_disturbing_the_other() {
    let svc = service(3, 2);
    let mut faulty = mixed(0, 20).with_seed(77);
    faulty.fault = Some(InjectedFault { on_attempt: 1, after_tasks: 2 });
    let clean = mixed(1, 20).with_seed(78);
    let hf = svc.submit(faulty.clone()).unwrap();
    let hc = svc.submit(clean.clone()).unwrap();
    let rf = hf.wait_timeout(SERVE_JOB_DEADLINE).unwrap();
    let rc = hc.wait_timeout(SERVE_JOB_DEADLINE).unwrap();
    // the faulty job restarted exactly once and still reproduced its
    // solo statistic; the clean one never restarted and matches too
    assert_eq!(rf.report.restarts, 1);
    assert_eq!(rf.output, solo_output(&faulty));
    assert_eq!(rc.report.restarts, 0);
    assert_eq!(rc.output, solo_output(&clean));
    let report = svc.shutdown().unwrap();
    assert_eq!(report.jobs_completed, 2);
    assert_eq!(report.jobs_failed, 0);
    // recovery reused the warm pool — no respawns even across restarts
    assert_eq!(report.workers_spawned, 3);
    assert_eq!(report.worker_respawns(), 0);
}

#[test]
fn persistent_fault_exhausts_attempts_and_fails_only_that_job() {
    let svc = service(2, 2);
    let mut doomed = mixed(0, 12).with_seed(5);
    doomed.fault = Some(InjectedFault { on_attempt: 0, after_tasks: 0 });
    doomed.max_attempts = 2;
    let neighbour = mixed(2, 12).with_seed(6);
    let hd = svc.submit(doomed).unwrap();
    let hn = svc.submit(neighbour.clone()).unwrap();
    let err = hd.wait_timeout(SERVE_JOB_DEADLINE).unwrap_err();
    match err {
        Error::JobFailed { attempts, cause } => {
            assert_eq!(attempts, 2);
            assert!(cause.contains("injected"), "cause: {cause}");
        }
        other => panic!("expected JobFailed, got {other}"),
    }
    // the neighbour is untouched, and the service keeps serving
    assert_eq!(
        hn.wait_timeout(SERVE_JOB_DEADLINE).unwrap().output,
        solo_output(&neighbour)
    );
    let late = svc.submit(mixed(1, 10).with_seed(9)).unwrap();
    assert!(late.wait_timeout(SERVE_JOB_DEADLINE).is_ok());
    let report = svc.shutdown().unwrap();
    assert_eq!(report.jobs_failed, 1);
    assert_eq!(report.jobs_completed, 2);
    assert_eq!(report.worker_respawns(), 0);
}

#[test]
fn serve_report_record_carries_the_percentiles() {
    let svc = service(2, 2);
    for i in 0..4 {
        svc.submit(mixed(i, 10).with_seed(i as u64))
            .unwrap()
            .wait_timeout(SERVE_JOB_DEADLINE)
            .unwrap();
    }
    let report = svc.shutdown().unwrap();
    let j = bts::util::json::Json::parse(
        &report.metrics_json().to_string_pretty(),
    )
    .unwrap();
    for field in [
        "jobs_completed",
        "tasks_per_s",
        "queue_wait_p50_s",
        "queue_wait_p95_s",
        "ttfp_p50_s",
        "e2e_p50_s",
        "e2e_p95_s",
        "workers_spawned",
        "worker_respawns",
        "speculated",
        "won_by_clone",
    ] {
        assert!(
            j.req_f64(field).is_ok(),
            "BENCH_serve record missing {field}"
        );
    }
    assert_eq!(j.req_usize("jobs_completed").unwrap(), 4);
    assert_eq!(j.req_usize("worker_respawns").unwrap(), 0);
}
