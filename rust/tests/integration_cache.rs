//! The cache subsystem end to end: determinism with the cache and
//! affinity dispatch enabled (solo, multiplexed, and through
//! job-level recovery), warm second tenants deduping against the
//! shared pool cache, and the per-job hit-rate metrics. Native
//! backend; no artifacts needed.

use std::sync::Arc;

use bts::data::{ModelParams, Workload};
use bts::exec::{
    run_cluster, run_cluster_with_recovery, Backend, ExecConfig,
};
use bts::coordinator::FailurePlan;
use bts::kneepoint::TaskSizing;
use bts::serve::{JobRequest, JobService, PoolConfig, ServeConfig};
use bts::workloads::build_small;

fn native() -> Arc<Backend> {
    Arc::new(Backend::native(ModelParams::default()))
}

fn cfg(cache_mb: usize, affinity: bool) -> ExecConfig {
    ExecConfig {
        sizing: TaskSizing::Kneepoint(16 * 1024),
        workers: 4,
        cache_mb,
        affinity,
        seed: 0xCAC4E,
        ..Default::default()
    }
}

#[test]
fn cache_and_affinity_never_change_the_statistic() {
    for w in [
        Workload::Eaglet,
        Workload::NetflixHi,
        Workload::SeqAddr,
        Workload::Ssag,
    ] {
        let ds = build_small(w, &ModelParams::default(), 24);
        let plain =
            run_cluster(ds.as_ref(), native(), &cfg(0, false)).unwrap();
        let cached =
            run_cluster(ds.as_ref(), native(), &cfg(32, false)).unwrap();
        let affine =
            run_cluster(ds.as_ref(), native(), &cfg(32, true)).unwrap();
        assert_eq!(
            plain.output,
            cached.output,
            "cache changed the {} statistic",
            w.name()
        );
        assert_eq!(
            plain.output,
            affine.output,
            "affinity dispatch changed the {} statistic",
            w.name()
        );
        // the cached run carries its counters
        let stats = cached.cache.expect("cache stats missing");
        assert!(
            stats.inserted > 0,
            "read-through fill never ran: {stats:?}"
        );
        assert!(plain.cache.is_none());
    }
}

#[test]
fn repeat_cached_runs_reproduce_bit_for_bit() {
    let ds = build_small(Workload::NetflixLo, &ModelParams::default(), 20);
    let a = run_cluster(ds.as_ref(), native(), &cfg(32, true)).unwrap();
    let b = run_cluster(ds.as_ref(), native(), &cfg(32, true)).unwrap();
    assert_eq!(a.output, b.output, "repeat run diverged with cache on");
}

#[test]
fn recovery_with_cache_reproduces_the_clean_result() {
    let ds = build_small(Workload::Eaglet, &ModelParams::default(), 25);
    let base = ExecConfig {
        sizing: TaskSizing::Tiniest,
        workers: 3,
        ..cfg(32, true)
    };
    let clean = run_cluster(ds.as_ref(), native(), &base).unwrap();
    let mut failing = base.clone();
    failing.failure = Some(FailurePlan {
        worker: 1,
        after_tasks: 2,
        on_attempt: 1,
    });
    let recovered =
        run_cluster_with_recovery(ds.as_ref(), native(), &failing, 3)
            .unwrap();
    assert_eq!(recovered.report.restarts, 1);
    assert_eq!(
        recovered.output, clean.output,
        "job-level recovery diverged with the cache enabled"
    );
}

#[test]
fn warm_second_tenant_dedupes_against_the_shared_cache() {
    let svc = JobService::start(
        native(),
        ServeConfig {
            pool: PoolConfig {
                workers: 4,
                cache_mb: 32,
                affinity: true,
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap();
    let req = JobRequest::new(Workload::Eaglet, 20)
        .with_seed(0xF00D)
        .with_sizing(TaskSizing::Kneepoint(16 * 1024));
    // cold tenant: every store fetch misses the empty cache
    let cold = svc.submit(req.clone()).unwrap().wait().unwrap();
    assert!(
        cold.report.cache_hit_rate < 0.5,
        "cold run hit rate {} — cache was not cold",
        cold.report.cache_hit_rate
    );
    // second tenant stages byte-identical blocks under its own job
    // namespace: staging aliases the resident content (dedup), so its
    // reads hit without refetching from the data nodes
    let warm = svc.submit(req.clone()).unwrap().wait().unwrap();
    assert!(
        warm.report.cache_hit_rate > 0.9,
        "warm tenant only hit {:.2} of its fetches",
        warm.report.cache_hit_rate
    );
    // identical request + per-job seeds: identical statistic
    assert_eq!(cold.output, warm.output);
    let report = svc.shutdown().unwrap();
    let stats = report.cache.expect("pool ran with a cache");
    assert!(
        stats.dedup_hits > 0,
        "cross-tenant dedup never fired: {stats:?}"
    );
    // the record surfaces the cache fields
    let j = bts::util::json::Json::parse(
        &report.metrics_json().to_string_pretty(),
    )
    .unwrap();
    assert!(j.req_f64("cache_hit_rate").unwrap() > 0.0);
    assert!(j.req_f64("cache_dedup_hits").unwrap() > 0.0);
}

#[test]
fn tenant_cleanup_keeps_namespaces_isolated() {
    // Different content must never dedupe: two workloads with
    // different bytes through one cached pool, interleaved, still
    // match their solo oracles.
    let svc = JobService::start(
        native(),
        ServeConfig {
            pool: PoolConfig {
                workers: 3,
                cache_mb: 16,
                affinity: true,
                ..Default::default()
            },
            max_active: 2,
            ..Default::default()
        },
    )
    .unwrap();
    let reqs: Vec<JobRequest> = (0..4)
        .map(|i| {
            let w = if i % 2 == 0 {
                Workload::Eaglet
            } else {
                Workload::NetflixHi
            };
            JobRequest::new(w, 16)
                .with_seed(0xA0 + i as u64)
                .with_sizing(TaskSizing::Kneepoint(16 * 1024))
        })
        .collect();
    let handles: Vec<_> = reqs
        .iter()
        .map(|r| svc.submit(r.clone()).unwrap())
        .collect();
    let results: Vec<_> =
        handles.into_iter().map(|h| h.wait().unwrap()).collect();
    for (req, res) in reqs.iter().zip(&results) {
        let ds =
            build_small(req.workload, &ModelParams::default(), req.samples);
        let solo = run_cluster(
            ds.as_ref(),
            native(),
            &ExecConfig {
                sizing: req.sizing,
                seed: req.seed,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(
            res.output,
            solo.output,
            "multiplexed cached job {} diverged from its solo run",
            res.id
        );
    }
    svc.shutdown().unwrap();
}
