//! The federation front-door end to end against the native backend.
//!
//! The load-bearing oracle: a job routed through the front-door — home
//! shard, spilled to a sibling, or re-homed after a leader kill — must
//! produce the **bit-identical** `JobOutput` a direct `JobService`
//! submission produces. The determinism contract (same seed, samples,
//! workload, reduce config ⇒ same statistic anywhere) is what makes
//! federation placement a pure performance decision; these tests pin
//! it across every routing path, including the framed-TCP wire.

use std::collections::HashMap;
use std::net::TcpListener;
use std::sync::Arc;
use std::thread;

use bts::coordinator::JobOutput;
use bts::data::{ModelParams, Workload};
use bts::exec::Backend;
use bts::federation::{
    frontdoor_shutdown, serve_frontdoor, submit_via_frontdoor, Federation,
    FederationConfig,
};
use bts::serve::{JobRequest, JobService};
use bts::util::testutil::SERVE_JOB_DEADLINE;

fn native() -> Arc<Backend> {
    Arc::new(Backend::native(ModelParams::default()))
}

fn fed_cfg() -> FederationConfig {
    FederationConfig {
        leaders: 2,
        workers_per_leader: 2,
        max_active_per_leader: 2,
        ..FederationConfig::default()
    }
}

/// Run `req` directly on one standalone leader with the exact pool
/// shape the federation gives each shard — the oracle every federated
/// job must match bit for bit.
fn direct_output(cfg: &FederationConfig, req: &JobRequest) -> JobOutput {
    let svc = JobService::start(native(), cfg.serve_config()).unwrap();
    let out = svc
        .submit(req.clone())
        .unwrap()
        .wait_timeout(SERVE_JOB_DEADLINE)
        .unwrap()
        .output;
    svc.shutdown().unwrap();
    out
}

fn mixed(i: usize, samples: usize) -> JobRequest {
    let workload = match i % 3 {
        0 => Workload::Eaglet,
        1 => Workload::NetflixHi,
        _ => Workload::NetflixLo,
    };
    JobRequest::new(workload, samples).with_seed(0xFED0 ^ (i as u64))
}

/// The first tenant name (within `prefix`0..) whose home shard is
/// `leader` — lets a test pin load onto a chosen shard.
fn tenant_homed_on(fed: &Federation, prefix: &str, leader: usize) -> String {
    (0u32..)
        .map(|i| format!("{prefix}{i}"))
        .find(|t| fed.home_leader(t) == leader)
        .unwrap()
}

#[test]
fn home_routed_jobs_match_direct_submission_bit_for_bit() {
    let cfg = fed_cfg();
    let mut fed = Federation::start(native(), cfg.clone()).unwrap();
    let mut ids: HashMap<u64, JobRequest> = HashMap::new();
    for i in 0..4 {
        let req = mixed(i, 12);
        let id = fed.submit(&format!("tenant-{i}"), req.clone()).unwrap();
        ids.insert(id, req);
    }
    fed.pump_until_idle(SERVE_JOB_DEADLINE).unwrap();
    let done = fed.drain_completions();
    assert_eq!(done.len(), 4);
    for c in done {
        let req = &ids[&c.id];
        let res = c.result.unwrap();
        assert_eq!(
            res.output,
            direct_output(&cfg, req),
            "job {} ({}) on leader {} diverged from its direct run",
            c.id,
            req.workload.name(),
            c.leader
        );
    }
    let report = fed.shutdown().unwrap();
    // 4 jobs against a per-leader outstanding cap of 4: every one of
    // them fit its home shard, so bit-identity above covered the pure
    // home-routed path
    assert_eq!(report.spilled, 0);
    assert_eq!(report.jobs_completed, 4);
    assert_eq!(report.jobs_failed, 0);
}

#[test]
fn spilled_jobs_match_direct_submission_bit_for_bit() {
    // Cap each shard at one outstanding job: a single tenant's burst
    // must overflow its home and spill to the sibling within the very
    // first dispatch sweep.
    let cfg = FederationConfig {
        leader_outstanding_cap: 1,
        ..fed_cfg()
    };
    let mut fed = Federation::start(native(), cfg.clone()).unwrap();
    let mut ids: HashMap<u64, JobRequest> = HashMap::new();
    for i in 0..4 {
        let req = JobRequest::new(Workload::NetflixLo, 10)
            .with_seed(0x5011 + i as u64);
        let id = fed.submit("spiller", req.clone()).unwrap();
        ids.insert(id, req);
    }
    fed.pump_until_idle(SERVE_JOB_DEADLINE).unwrap();
    let done = fed.drain_completions();
    assert_eq!(done.len(), 4);
    assert!(
        done.iter().any(|c| c.spilled),
        "a saturated home must spill, not queue forever"
    );
    for c in done {
        let req = &ids[&c.id];
        let res = c.result.unwrap();
        assert_eq!(
            res.output,
            direct_output(&cfg, req),
            "job {} (spilled={}, leader {}) diverged from its direct run",
            c.id,
            c.spilled,
            c.leader
        );
    }
    let report = fed.shutdown().unwrap();
    assert!(report.spilled >= 1);
    assert_eq!(report.jobs_failed, 0);
}

#[test]
fn tcp_frontdoor_output_matches_direct_submission() {
    let cfg = fed_cfg();
    let fed = Federation::start(native(), cfg.clone()).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server = thread::spawn(move || serve_frontdoor(listener, fed));
    let req = JobRequest::new(Workload::Eaglet, 16).with_seed(0x7CB);
    let out = submit_via_frontdoor(&addr, "wire-tenant", &req).unwrap();
    assert_eq!(
        out.output,
        direct_output(&cfg, &req),
        "the framed-TCP round trip must not perturb the statistic"
    );
    frontdoor_shutdown(&addr).unwrap();
    let report = server.join().unwrap().unwrap();
    assert_eq!(report.jobs_completed, 1);
    assert_eq!(report.jobs_failed, 0);
}

#[test]
fn killing_a_leader_rehomes_without_corrupting_survivors() {
    let cfg = fed_cfg();
    let mut fed = Federation::start(native(), cfg.clone()).unwrap();
    // one tenant homed on each shard, so the kill hits exactly one of
    // them and the other doubles as the untouched control
    let victim = tenant_homed_on(&fed, "a", 0);
    let control = tenant_homed_on(&fed, "b", 1);
    let mk = |seed: u64| {
        JobRequest::new(Workload::NetflixHi, 10).with_seed(seed)
    };
    let mut ids: HashMap<u64, JobRequest> = HashMap::new();
    for (tenant, seed) in [(&victim, 1u64), (&control, 2)] {
        let req = mk(seed);
        ids.insert(fed.submit(tenant, req.clone()).unwrap(), req);
    }
    fed.pump_until_idle(SERVE_JOB_DEADLINE).unwrap();
    fed.kill_leader(0).unwrap();
    for (tenant, seed) in [(&victim, 3u64), (&control, 4)] {
        let req = mk(seed);
        ids.insert(fed.submit(tenant, req.clone()).unwrap(), req);
    }
    fed.pump_until_idle(SERVE_JOB_DEADLINE).unwrap();
    let done = fed.drain_completions();
    assert_eq!(done.len(), 4);
    for c in &done {
        let req = &ids[&c.id];
        let output = match &c.result {
            Ok(res) => &res.output,
            Err(e) => panic!("job {} for {} failed: {e}", c.id, c.tenant),
        };
        assert_eq!(
            output,
            &direct_output(&cfg, req),
            "job {} for {} (leader {}) diverged after the kill",
            c.id,
            c.tenant,
            c.leader
        );
    }
    // every post-kill job — the victim's re-homed one *and* the
    // control's — ran on the surviving shard
    let post_kill: Vec<_> = done.iter().filter(|c| c.id > 2).collect();
    assert_eq!(post_kill.len(), 2);
    assert!(post_kill.iter().all(|c| c.leader == 1));
    let report = fed.shutdown().unwrap();
    assert!(report.rehomed >= 1, "the victim's job re-homed");
    assert_eq!(report.jobs_completed, 4);
    assert_eq!(report.jobs_failed, 0);
}
