//! End-to-end jobs through the full stack: pack → schedule → dfs fetch →
//! PJRT map → shuffle → PJRT reduce. Needs `make artifacts`.

use std::sync::Arc;

use bts::coordinator::{run_job, JobConfig, JobOutput};
use bts::data::eaglet::{EagletConfig, EagletDataset};
use bts::data::netflix::{NetflixConfig, NetflixDataset};
use bts::data::{Dataset, Workload};
use bts::dfs::LatencyModel;
use bts::kneepoint::TaskSizing;
use bts::runtime::{Manifest, Runtime};

fn manifest() -> Option<Arc<Manifest>> {
    match Manifest::load("artifacts") {
        Ok(m) => Some(Arc::new(m)),
        Err(_) => {
            eprintln!("skipping: run `make artifacts` first");
            None
        }
    }
}

fn small_eaglet(m: &Manifest) -> EagletDataset {
    EagletDataset::generate(
        &m.params,
        EagletConfig { families: 40, ..Default::default() },
    )
}

fn small_netflix(m: &Manifest, hi: bool) -> NetflixDataset {
    NetflixDataset::generate(
        &m.params,
        NetflixConfig {
            movies: 60,
            high_confidence: hi,
            ..Default::default()
        },
    )
}

#[test]
fn eaglet_job_end_to_end() {
    let Some(m) = manifest() else { return };
    let ds = small_eaglet(&m);
    let cfg = JobConfig {
        sizing: TaskSizing::Kneepoint(16 * 1024),
        workers: 4,
        ..Default::default()
    };
    let r = run_job(&ds, m.clone(), &cfg).unwrap();
    let JobOutput::Eaglet { alod, weight } = &r.output else {
        panic!("wrong output kind")
    };
    assert_eq!(alod.len(), m.params.grid);
    assert!(alod.iter().all(|v| v.is_finite()));
    // total weight == total chunks in the dataset, regardless of packing
    let chunks: f32 =
        ds.metas().iter().map(|meta| meta.units as f32).sum();
    assert!(
        (weight - chunks).abs() < 1e-3,
        "weight {weight} != total chunks {chunks}"
    );
    assert_eq!(r.report.tasks, r.sched.assigned as usize);
    assert!(r.report.total_s > 0.0);
    assert!(r.report.throughput_mbs() > 0.0);
}

#[test]
fn worker_count_does_not_change_the_statistic() {
    // Subsample indices are seeded per task, partials are reduced in seq
    // order → the statistic must be bit-identical across parallelism.
    let Some(m) = manifest() else { return };
    let ds = small_eaglet(&m);
    let base = JobConfig {
        sizing: TaskSizing::Kneepoint(16 * 1024),
        ..Default::default()
    };
    let r1 = run_job(
        &ds,
        m.clone(),
        &JobConfig { workers: 1, ..base.clone() },
    )
    .unwrap();
    let r4 = run_job(
        &ds,
        m.clone(),
        &JobConfig { workers: 4, ..base.clone() },
    )
    .unwrap();
    assert_eq!(r1.output, r4.output, "parallelism changed the answer");
}

#[test]
fn sizing_policies_conserve_weight() {
    let Some(m) = manifest() else { return };
    let ds = small_eaglet(&m);
    let chunks: f32 =
        ds.metas().iter().map(|meta| meta.units as f32).sum();
    for sizing in [
        TaskSizing::Tiniest,
        TaskSizing::Kneepoint(8 * 1024),
        TaskSizing::LargeSn { workers: 3 },
    ] {
        let cfg = JobConfig { sizing, workers: 3, ..Default::default() };
        let r = run_job(&ds, m.clone(), &cfg).unwrap();
        let JobOutput::Eaglet { weight, .. } = r.output else {
            panic!("wrong kind")
        };
        assert!(
            (weight - chunks).abs() < 1e-2,
            "{sizing:?}: weight {weight} != {chunks}"
        );
    }
}

#[test]
fn netflix_job_produces_sane_stats() {
    let Some(m) = manifest() else { return };
    for hi in [false, true] {
        let ds = small_netflix(&m, hi);
        let cfg = JobConfig {
            sizing: TaskSizing::Kneepoint(64 * 1024),
            workers: 2,
            ..Default::default()
        };
        let r = run_job(&ds, m.clone(), &cfg).unwrap();
        let JobOutput::Netflix(stats) = &r.output else {
            panic!("wrong kind")
        };
        let mut rated_months = 0;
        for mo in 0..m.params.months {
            if stats.count[mo] > 0.0 {
                rated_months += 1;
                assert!(
                    stats.mean[mo] >= 1.0 && stats.mean[mo] <= 5.0,
                    "month {mo} mean {} out of rating range",
                    stats.mean[mo]
                );
                assert!(stats.ci_half[mo].is_finite());
            }
        }
        assert!(rated_months >= 6, "only {rated_months} months rated");
        // counts cannot exceed the total subsample draws, and should
        // track the dataset's valid-rating density (draws land on padded
        // slots with probability 1 - density).
        let total: f64 = stats.count.iter().sum();
        let s = if hi { m.params.s_hi } else { m.params.s_lo };
        let draws = (ds.metas().len() * s) as f64;
        let density = ds
            .movies
            .iter()
            .map(|mv| mv.n_ratings as f64)
            .sum::<f64>()
            / (ds.movies.len() * m.params.ratings_cap) as f64;
        assert!(total <= draws + 0.5, "count {total} exceeds draws {draws}");
        let want = draws * density;
        assert!(
            (total - want).abs() < want * 0.5,
            "count {total} far from expected {want} (density {density:.3})"
        );
    }
}

#[test]
fn direct_oracle_matches_platform_result() {
    // Execute the same packed tasks directly (no dfs, no scheduler, one
    // runtime) and f64-reduce on the host: the platform must agree.
    let Some(m) = manifest() else { return };
    let ds = small_eaglet(&m);
    let sizing = TaskSizing::Tiniest;
    let cfg = JobConfig { sizing, workers: 4, ..Default::default() };
    let r = run_job(&ds, m.clone(), &cfg).unwrap();
    let JobOutput::Eaglet { alod, weight } = &r.output else {
        panic!("wrong kind")
    };

    use bts::coordinator::assemble::{MapTask, TaskPartial};
    use bts::scheduler::TaskSpec;
    let rt = Runtime::new(m.clone()).unwrap();
    let tasks = bts::kneepoint::pack(ds.metas(), sizing);
    let mut wsum = vec![0.0f64; m.params.grid];
    let mut wtot = 0.0f64;
    for t in tasks {
        let spec = TaskSpec::new(t, Workload::Eaglet, cfg.seed);
        let blocks: Vec<_> = spec
            .task
            .sample_ids
            .iter()
            .map(|&id| ds.encode_block(id))
            .collect();
        let slices =
            MapTask::slices(&m.params, Workload::Eaglet, &blocks, spec.seed)
                .unwrap();
        let mut parts = Vec::new();
        for s in &slices {
            let e = rt.manifest.entry(s.kind, s.bucket).unwrap().clone();
            let out = rt.execute(&e, &s.inputs).unwrap();
            parts.push(
                TaskPartial::from_map_output(&m.params, s, &out[0]).unwrap(),
            );
        }
        match TaskPartial::merge(parts).unwrap() {
            TaskPartial::Eaglet { alod, weight } => {
                for (acc, v) in wsum.iter_mut().zip(&alod) {
                    *acc += *v as f64 * weight as f64;
                }
                wtot += weight as f64;
            }
            _ => unreachable!(),
        }
    }
    assert!((wtot - *weight as f64).abs() < 1e-3);
    for (i, (want, got)) in wsum
        .iter()
        .map(|v| v / wtot)
        .zip(alod.iter())
        .enumerate()
    {
        assert!(
            (want - *got as f64).abs() < 1e-3,
            "grid point {i}: oracle {want} vs platform {got}"
        );
    }
}

#[test]
fn monitoring_collects_a_record_per_task_plus_registration() {
    let Some(m) = manifest() else { return };
    let ds = small_eaglet(&m);
    let cfg = JobConfig {
        sizing: TaskSizing::Tiniest,
        workers: 2,
        monitoring: true,
        ..Default::default()
    };
    let r = run_job(&ds, m.clone(), &cfg).unwrap();
    assert_eq!(r.monitor_records, r.report.tasks + cfg.workers);
}

#[test]
fn adaptive_rf_reacts_to_slow_data_nodes() {
    let Some(m) = manifest() else { return };
    let ds = small_eaglet(&m);
    // lan latency + sleep makes fetches genuinely slow relative to tiny
    // task execution → the controller must widen the replica set.
    let cfg = JobConfig {
        sizing: TaskSizing::Tiniest,
        workers: 4,
        data_nodes: 8,
        latency: LatencyModel::lan(),
        adaptive_rf: true,
        ..Default::default()
    };
    let r = run_job(&ds, m.clone(), &cfg).unwrap();
    assert!(!r.rf_trajectory.is_empty());
    assert!(r.report.final_rf >= 1);
    assert!(r.report.prefetch_hit_rate >= 0.0);
}

#[test]
fn prefetcher_hides_fetches_on_multi_task_queues() {
    let Some(m) = manifest() else { return };
    let ds = small_eaglet(&m);
    let cfg = JobConfig {
        sizing: TaskSizing::Tiniest,
        workers: 2,
        latency: LatencyModel::lan(),
        prefetch_k: 8,
        ..Default::default()
    };
    let r = run_job(&ds, m.clone(), &cfg).unwrap();
    // With 40 tiny tasks on 2 workers and k up to 8, a decent share of
    // fetches should be prefetch hits.
    assert!(
        r.report.prefetch_hit_rate > 0.2,
        "hit rate {}",
        r.report.prefetch_hit_rate
    );
}
