//! Elastic membership oracle suite (DESIGN.md §14).
//!
//! The contract under test: membership churn — a worker joining
//! mid-job, draining mid-job, or dying mid-job (map phase and shuffle
//! boundary) — must never change the [`JobOutput`]. Every elastic run
//! is diffed bit-for-bit against a static in-proc baseline, with
//! `report.restarts == 0` (the ledger re-dispatches in-flight work,
//! it does not restart the job) and the re-dispatch volume bounded by
//! the lost slot's in-flight window.
//!
//! Also covered here, as regression tests for the listener-lifecycle
//! fix: a late `bts worker --connect` is admitted when the membership
//! is elastic and refused with a versioned error frame when it is
//! frozen — never left hanging in the backlog. And the
//! shuffle-fragment unstaging contract: the shared replicated store's
//! byte footprint returns to its pre-job level after reduce jobs
//! retire, including after a mid-shuffle worker loss (no leaked
//! `shuffle_key` entries).

use std::sync::Arc;
use std::thread;
use std::time::Duration;

use bts::data::{ModelParams, Workload};
use bts::dfs::LatencyModel;
use bts::exec::{run_cluster, Backend, ExecConfig};
use bts::kneepoint::TaskSizing;
use bts::net::{request_drain, run_worker};
use bts::reduce::Partitioner;
use bts::scheduler::SchedConfig;
use bts::serve::{JobRequest, JobService, PoolConfig, ServeConfig};
use bts::transport::{RemoteWorkerOpts, RemoteWorkers};
use bts::util::testutil::{Turbulence, SERVE_JOB_DEADLINE};
use bts::workloads::build_small;

fn native() -> Arc<Backend> {
    Arc::new(Backend::native(ModelParams::default()))
}

fn params() -> ModelParams {
    ModelParams::default()
}

const SEED: u64 = 0xB75;

/// A slow-but-real data plane: paces the job so membership events
/// scripted in wall-clock (drains, late joins) reliably land mid-job.
fn paced() -> LatencyModel {
    LatencyModel {
        base_s: 2e-3,
        per_mib_s: 0.0,
        per_inflight_s: 1e-3,
        sleep: true,
    }
}

/// A worker killed mid-map must cost a ledger re-dispatch of its
/// in-flight window — never a restart, never a different statistic.
#[test]
fn killed_worker_mid_map_matches_static_baseline_on_both_workloads() {
    for workload in [Workload::Eaglet, Workload::NetflixLo] {
        let backend = native();
        let ds = build_small(workload, &params(), 30);
        let base = ExecConfig {
            sizing: TaskSizing::Tiniest,
            seed: SEED,
            workers: 3,
            ..Default::default()
        };
        let reference =
            run_cluster(ds.as_ref(), backend.clone(), &base).unwrap();

        // Worker 1 starts with a full dispatch window, so its third
        // task deterministically exists: the kill always fires.
        let killed = run_cluster(
            ds.as_ref(),
            backend,
            &ExecConfig {
                elastic: true,
                turbulence: Some(Arc::new(
                    Turbulence::new(SEED).kill_at(1, 2),
                )),
                ..base.clone()
            },
        )
        .unwrap();

        assert_eq!(
            killed.output, reference.output,
            "{workload:?}: elastic loss absorption changed the statistic"
        );
        assert_eq!(
            killed.report.restarts, 0,
            "{workload:?}: worker loss must not cost a job-level restart"
        );
        assert!(
            killed.re_dispatched >= 1,
            "{workload:?}: the dead slot held in-flight work; the \
             ledger must re-dispatch it"
        );
        assert!(
            killed.re_dispatched <= base.inflight as u64,
            "{workload:?}: re-dispatch must cover only the lost \
             in-flight window, got {} > {}",
            killed.re_dispatched,
            base.inflight
        );
        assert!(
            !killed.workers[1].clean_shutdown,
            "{workload:?}: the killed slot must be recorded as unclean"
        );
    }
}

/// Same contract at the shuffle boundary: a reduce-heavy job loses a
/// worker around the map→shuffle handoff and still reproduces the
/// executed-reduce statistic.
#[test]
fn killed_worker_at_shuffle_boundary_matches_reduce_baseline() {
    let backend = native();
    let ds = build_small(Workload::NetflixLo, &params(), 12);
    let base = ExecConfig {
        sizing: TaskSizing::Tiniest,
        seed: SEED,
        workers: 3,
        reduce_tasks: 6,
        partitioner: Partitioner::Hash,
        ..Default::default()
    };
    let reference =
        run_cluster(ds.as_ref(), backend.clone(), &base).unwrap();

    // 12 map tasks over 3 slots fill each initial window exactly;
    // worker 2's fifth unit (nth = 4) arrives with the refill at the
    // shuffle handoff.
    let killed = run_cluster(
        ds.as_ref(),
        backend,
        &ExecConfig {
            elastic: true,
            turbulence: Some(Arc::new(Turbulence::new(SEED).kill_at(2, 4))),
            ..base.clone()
        },
    )
    .unwrap();

    assert_eq!(
        killed.output, reference.output,
        "loss at the shuffle boundary changed the reduced statistic"
    );
    assert_eq!(killed.report.restarts, 0);
    assert!(
        killed.re_dispatched <= base.inflight as u64,
        "re-dispatch exceeded the lost in-flight window: {}",
        killed.re_dispatched
    );
}

/// Cache and speculation layered on top of a mid-job loss must leave
/// the statistic bit-identical to the plain static baseline.
#[test]
fn cache_and_speculation_on_elastic_loss_stay_bit_identical() {
    let backend = native();
    let ds = build_small(Workload::Eaglet, &params(), 24);
    let reference = run_cluster(
        ds.as_ref(),
        backend.clone(),
        &ExecConfig {
            sizing: TaskSizing::Tiniest,
            seed: SEED,
            workers: 3,
            ..Default::default()
        },
    )
    .unwrap();

    let fancy = run_cluster(
        ds.as_ref(),
        backend,
        &ExecConfig {
            sizing: TaskSizing::Tiniest,
            seed: SEED,
            workers: 3,
            elastic: true,
            cache_mb: 16,
            sched: SchedConfig {
                dynamic: true,
                speculate: true,
                straggler_pct: 95.0,
                ..Default::default()
            },
            turbulence: Some(Arc::new(Turbulence::new(SEED).kill_at(0, 3))),
            ..Default::default()
        },
    )
    .unwrap();

    assert_eq!(
        fancy.output, reference.output,
        "cache + speculation + elastic loss changed the statistic"
    );
    assert_eq!(fancy.report.restarts, 0);
    assert!(fancy.cache.is_some(), "the cache was attached");
}

/// A late `bts worker --connect` against an elastic leader is admitted
/// mid-job, executes real work, and the grown membership still
/// reproduces the static baseline bit-for-bit.
#[test]
fn late_tcp_joiner_is_admitted_into_an_elastic_job() {
    let backend = native();
    let ds = build_small(Workload::Eaglet, &params(), 24);
    let reference = run_cluster(
        ds.as_ref(),
        backend.clone(),
        &ExecConfig {
            sizing: TaskSizing::Tiniest,
            seed: SEED,
            workers: 2,
            ..Default::default()
        },
    )
    .unwrap();

    // Zero initial remote workers: the listener is open purely for
    // late joiners. The lone local slot is paced at 5ms/task so the
    // job is still deep in its map phase when the joiner connects.
    let remote = RemoteWorkers::bind("127.0.0.1:0", 0).unwrap();
    let addr = remote.addr();
    let joiner = thread::spawn(move || {
        thread::sleep(Duration::from_millis(15));
        run_worker(&addr, native(), &RemoteWorkerOpts::default())
    });
    let elastic = run_cluster(
        ds.as_ref(),
        backend,
        &ExecConfig {
            sizing: TaskSizing::Tiniest,
            seed: SEED,
            workers: 1,
            remote: Some(remote),
            elastic: true,
            turbulence: Some(Arc::new(Turbulence::new(SEED).slow_from(
                0,
                0,
                Duration::from_millis(5),
            ))),
            ..Default::default()
        },
    )
    .unwrap();

    let executed = joiner
        .join()
        .unwrap()
        .expect("the late joiner must be admitted, not refused or hung");
    assert!(
        executed > 0,
        "the admitted joiner never executed anything"
    );
    assert_eq!(
        elastic.workers.len(),
        2,
        "the membership must have grown by the joiner"
    );
    assert_eq!(
        elastic.output, reference.output,
        "a mid-job join changed the statistic"
    );
    assert_eq!(elastic.report.restarts, 0);
}

/// A frozen (non-elastic) membership refuses a late connect with the
/// versioned error frame — promptly, and without disturbing the pool,
/// which keeps serving afterwards.
#[test]
fn late_connect_to_frozen_membership_is_refused_not_hung() {
    let backend = native();
    let ds = build_small(Workload::Eaglet, &params(), 16);
    let solo = run_cluster(
        ds.as_ref(),
        backend.clone(),
        &ExecConfig {
            sizing: TaskSizing::Tiniest,
            seed: SEED,
            workers: 2,
            ..Default::default()
        },
    )
    .unwrap();

    let remote = RemoteWorkers::bind("127.0.0.1:0", 1).unwrap();
    let addr = remote.addr();
    let initial = thread::spawn({
        let addr = addr.clone();
        move || {
            run_worker(&addr, native(), &RemoteWorkerOpts::default())
                .expect("initial remote worker session")
        }
    });
    // elastic stays off: the membership freezes once the initial
    // quota (1 remote slot) is filled.
    let svc = JobService::start(
        backend,
        ServeConfig {
            pool: PoolConfig {
                workers: 1,
                remote: Some(remote),
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap();

    // The pool outlives this call, so there is no shutdown race: the
    // refusal below is the acceptor's answer, not a closed port.
    let err = run_worker(&addr, native(), &RemoteWorkerOpts::default())
        .expect_err("a frozen membership must refuse the late connect");
    let msg = err.to_string();
    assert!(
        msg.contains("frozen") && msg.contains("protocol v"),
        "refusal must be the versioned membership frame, got: {msg}"
    );

    // The refusal must not have cost the pool anything.
    let r = svc
        .submit(
            JobRequest::new(Workload::Eaglet, 16)
                .with_seed(SEED)
                .with_sizing(TaskSizing::Tiniest),
        )
        .unwrap()
        .wait_timeout(SERVE_JOB_DEADLINE)
        .unwrap();
    let report = svc.shutdown().unwrap();
    initial.join().unwrap();
    assert_eq!(r.output, solo.output, "pool output diverged after refusal");
    assert_eq!(report.workers, 2, "1 local + 1 remote slot, no growth");
    assert_eq!(report.jobs_failed, 0);
}

/// `bts drain <worker>` against a live elastic leader: the drained
/// slot hands its queue back and exits clean, survivors absorb the
/// work, and the statistic is unchanged.
#[test]
fn drained_tcp_worker_mid_job_matches_baseline() {
    let backend = native();
    let ds = build_small(Workload::Eaglet, &params(), 40);
    let reference = run_cluster(
        ds.as_ref(),
        backend.clone(),
        &ExecConfig {
            sizing: TaskSizing::Tiniest,
            seed: SEED,
            workers: 3,
            ..Default::default()
        },
    )
    .unwrap();

    let remote = RemoteWorkers::bind("127.0.0.1:0", 2).unwrap();
    let addr = remote.addr();
    let workers: Vec<_> = (0..2)
        .map(|_| {
            let addr = addr.clone();
            thread::spawn(move || {
                run_worker(&addr, native(), &RemoteWorkerOpts::default())
                    .expect("remote worker session")
            })
        })
        .collect();
    // Ask the leader to drain slot 2 (the second remote) once the job
    // is under way; the paced data plane keeps it running well past
    // the request.
    let drainer = thread::spawn({
        let addr = addr.clone();
        move || {
            thread::sleep(Duration::from_millis(15));
            request_drain(&addr, 2)
        }
    });
    let elastic = run_cluster(
        ds.as_ref(),
        backend,
        &ExecConfig {
            sizing: TaskSizing::Tiniest,
            seed: SEED,
            workers: 1,
            remote: Some(remote),
            elastic: true,
            latency: paced(),
            ..Default::default()
        },
    )
    .unwrap();
    drainer
        .join()
        .unwrap()
        .expect("the leader must ack the drain request");
    for h in workers {
        h.join().unwrap();
    }

    assert_eq!(
        elastic.output, reference.output,
        "a mid-job drain changed the statistic"
    );
    assert_eq!(
        elastic.report.restarts, 0,
        "a graceful drain must never cost a restart"
    );
}

/// Serve-layer half of the loss contract: an elastic pool absorbs a
/// killed slot with a per-tenant ledger re-dispatch (no tenant
/// restart), and the job's sample blocks *and* shuffle fragments are
/// unstaged at retirement — the store footprint returns to its
/// pre-job level even after a mid-shuffle worker loss.
#[test]
fn elastic_pool_absorbs_loss_and_unstages_the_store() {
    let backend = native();
    let ds = build_small(Workload::Eaglet, &params(), 20);
    let solo = run_cluster(
        ds.as_ref(),
        backend.clone(),
        &ExecConfig {
            sizing: TaskSizing::Tiniest,
            seed: SEED,
            workers: 2,
            reduce_tasks: 4,
            partitioner: Partitioner::Hash,
            ..Default::default()
        },
    )
    .unwrap();

    let svc = JobService::start(
        backend,
        ServeConfig {
            pool: PoolConfig {
                workers: 2,
                elastic: true,
                // Pace the slots so both share the job and the kill
                // reliably fires mid-run.
                latency: LatencyModel {
                    base_s: 1e-3,
                    per_mib_s: 0.0,
                    per_inflight_s: 0.0,
                    sleep: true,
                },
                turbulence: Some(Arc::new(
                    Turbulence::new(SEED).kill_at(1, 3),
                )),
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap();
    let req = JobRequest::new(Workload::Eaglet, 20)
        .with_seed(SEED)
        .with_sizing(TaskSizing::Tiniest)
        .with_reduce(4, Partitioner::Hash);
    let r = svc.submit(req).unwrap().wait_timeout(SERVE_JOB_DEADLINE).unwrap();
    let report = svc.shutdown().unwrap();

    assert_eq!(
        r.output, solo.output,
        "ledger re-dispatch in the pool changed the statistic"
    );
    assert_eq!(
        r.report.restarts, 0,
        "elastic loss must be absorbed without a tenant restart"
    );
    assert_eq!(report.jobs_failed, 0);
    assert_eq!(
        report.dfs_stored_bytes, 0,
        "worker loss leaked staged blocks or shuffle_key entries"
    );
}

/// Clean-path half of the unstaging contract: back-to-back reduce
/// jobs each stage shuffle fragments, each retirement removes them,
/// and the session ends at the pre-job footprint.
#[test]
fn store_footprint_returns_to_pre_job_level_after_reduce_jobs() {
    let backend = native();
    let svc = JobService::start(
        backend,
        ServeConfig {
            pool: PoolConfig { workers: 2, ..Default::default() },
            ..Default::default()
        },
    )
    .unwrap();
    for i in 0..2u64 {
        let req = JobRequest::new(Workload::NetflixLo, 18)
            .with_seed(SEED ^ i)
            .with_sizing(TaskSizing::Tiniest)
            .with_reduce(4, Partitioner::Skew);
        svc.submit(req)
            .unwrap()
            .wait_timeout(SERVE_JOB_DEADLINE)
            .unwrap();
    }
    let report = svc.shutdown().unwrap();
    assert_eq!(report.jobs_completed, 2);
    assert!(
        report.shuffle_bytes > 0,
        "the reduce jobs must have staged shuffle fragments"
    );
    assert_eq!(
        report.dfs_stored_bytes, 0,
        "retired jobs left blocks in the shared store"
    );
}
