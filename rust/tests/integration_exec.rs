//! The `exec` cluster executor end to end, against the native kernel
//! backend — no artifacts, no XLA runtime needed, so these run on
//! every host.

use std::sync::Arc;

use bts::coordinator::assemble::{execute_slices, MapTask, TaskPartial};
use bts::coordinator::{FailurePlan, JobOutput};
use bts::data::{Dataset, ModelParams, Workload};
use bts::error::Error;
use bts::exec::{
    run_cluster, run_cluster_with_recovery, Backend, ExecConfig,
};
use bts::kneepoint::TaskSizing;
use bts::scheduler::TaskSpec;
use bts::workloads::build_small;

fn native() -> Arc<Backend> {
    Arc::new(Backend::native(ModelParams::default()))
}

fn params() -> ModelParams {
    ModelParams::default()
}

#[test]
fn eaglet_cluster_matches_serial_oracle() {
    // Execute the same packed tasks serially through the native backend
    // and f64-reduce on the host: the channel cluster must agree.
    let backend = native();
    let p = params();
    let ds = build_small(Workload::Eaglet, &p, 40);
    let sizing = TaskSizing::Kneepoint(16 * 1024);
    let cfg = ExecConfig { sizing, workers: 4, ..Default::default() };
    let r = run_cluster(ds.as_ref(), backend.clone(), &cfg).unwrap();
    let JobOutput::Eaglet { alod, weight } = &r.output else {
        panic!("wrong output kind")
    };
    assert_eq!(alod.len(), p.grid);
    assert!(alod.iter().all(|v| v.is_finite()));

    let tasks = bts::kneepoint::pack(ds.metas(), sizing);
    let mut wsum = vec![0.0f64; p.grid];
    let mut wtot = 0.0f64;
    for t in tasks {
        let spec = TaskSpec::new(t, Workload::Eaglet, cfg.seed);
        let blocks: Vec<_> = spec
            .task
            .sample_ids
            .iter()
            .map(|&id| ds.encode_block(id))
            .collect();
        let slices =
            MapTask::slices(&p, Workload::Eaglet, &blocks, spec.seed).unwrap();
        // Same map path as the cluster workers; the oracle's
        // independence is the host-side f64 reduce below.
        match execute_slices(backend.as_ref(), &p, slices).unwrap() {
            TaskPartial::Eaglet { alod, weight } => {
                for (acc, v) in wsum.iter_mut().zip(&alod) {
                    *acc += *v as f64 * weight as f64;
                }
                wtot += weight as f64;
            }
            _ => unreachable!(),
        }
    }
    assert!((wtot - *weight as f64).abs() < 1e-2);
    for (i, (want, got)) in
        wsum.iter().map(|v| v / wtot).zip(alod.iter()).enumerate()
    {
        assert!(
            (want - *got as f64).abs() < 1e-2 * want.abs().max(1.0),
            "grid point {i}: oracle {want} vs cluster {got}"
        );
    }
    // total weight == total chunks, regardless of packing
    let chunks: f64 = ds.metas().iter().map(|m| m.units as f64).sum();
    assert!((*weight as f64 - chunks).abs() < 1e-2);
}

#[test]
fn worker_count_does_not_change_the_statistic() {
    let backend = native();
    let ds = build_small(Workload::Eaglet, &params(), 30);
    let base = ExecConfig {
        sizing: TaskSizing::Kneepoint(16 * 1024),
        ..Default::default()
    };
    let r1 = run_cluster(
        ds.as_ref(),
        backend.clone(),
        &ExecConfig { workers: 1, ..base.clone() },
    )
    .unwrap();
    let r4 = run_cluster(
        ds.as_ref(),
        backend.clone(),
        &ExecConfig { workers: 4, ..base.clone() },
    )
    .unwrap();
    assert_eq!(r1.output, r4.output, "parallelism changed the answer");
}

#[test]
fn netflix_cluster_produces_sane_stats() {
    let backend = native();
    let p = params();
    for w in [Workload::NetflixHi, Workload::NetflixLo] {
        let ds = build_small(w, &p, 60);
        let cfg = ExecConfig {
            sizing: TaskSizing::Kneepoint(512 * 1024),
            workers: 3,
            ..Default::default()
        };
        let r = run_cluster(ds.as_ref(), backend.clone(), &cfg).unwrap();
        let JobOutput::Netflix(stats) = &r.output else {
            panic!("wrong output kind")
        };
        let mut rated = 0;
        for mo in 0..p.months {
            if stats.count[mo] > 0.0 {
                rated += 1;
                assert!(
                    stats.mean[mo] >= 1.0 && stats.mean[mo] <= 5.0,
                    "month {mo} mean {} out of rating range",
                    stats.mean[mo]
                );
                assert!(stats.ci_half[mo].is_finite());
            }
        }
        assert!(rated >= 6, "only {rated} months rated");
        let total: f64 = stats.count.iter().sum();
        let s = if w == Workload::NetflixHi { p.s_hi } else { p.s_lo };
        let draws = (ds.metas().len() * s) as f64;
        assert!(total <= draws + 0.5, "count {total} exceeds draws {draws}");
    }
}

#[test]
fn seqaddr_cluster_matches_expected_moments() {
    // SeqAddr rides the Netflix moment algebra: every sample draws
    // exactly `sa_rounds` windows, so the summed count lane is a
    // closed-form invariant regardless of packing or parallelism.
    let backend = native();
    let p = params();
    let samples = 30;
    let ds = build_small(Workload::SeqAddr, &p, samples);
    let cfg = ExecConfig {
        sizing: TaskSizing::Kneepoint(16 * 1024),
        workers: 3,
        ..Default::default()
    };
    let r = run_cluster(ds.as_ref(), backend, &cfg).unwrap();
    let JobOutput::Netflix(stats) = &r.output else {
        panic!("wrong output kind")
    };
    assert_eq!(stats.mean.len(), p.sa_bins);
    let total: f64 = stats.count.iter().sum();
    assert_eq!(total, (samples * p.sa_rounds) as f64);
    for (b, (mean, n)) in
        stats.mean.iter().zip(&stats.count).enumerate()
    {
        if *n > 0.0 {
            assert!(mean.is_finite(), "bin {b} mean not finite");
        }
    }
}

#[test]
fn ssag_cluster_produces_a_positive_variance_ladder() {
    // SSAG rides the EAGLET weighted-mean algebra: the output curve is
    // b_g · Var(block means) per ladder rung, strictly positive for
    // non-constant series, with total weight = series count.
    let backend = native();
    let p = params();
    let samples = 24;
    let ds = build_small(Workload::Ssag, &p, samples);
    let cfg = ExecConfig {
        sizing: TaskSizing::Kneepoint(8 * 1024),
        workers: 3,
        ..Default::default()
    };
    let r = run_cluster(ds.as_ref(), backend, &cfg).unwrap();
    let JobOutput::Eaglet { alod, weight } = &r.output else {
        panic!("wrong output kind")
    };
    assert_eq!(alod.len(), p.ssag_points);
    assert!(alod.iter().all(|v| v.is_finite() && *v > 0.0), "{alod:?}");
    assert!((*weight - samples as f32).abs() < 1e-3);
}

#[test]
fn new_kernels_recover_bit_identically() {
    // Determinism through job-level recovery, same contract the
    // original pair pins in `recovery_restarts_and_reproduces…`.
    let backend = native();
    for w in [Workload::SeqAddr, Workload::Ssag] {
        let ds = build_small(w, &params(), 20);
        let cfg = ExecConfig {
            sizing: TaskSizing::Tiniest,
            workers: 3,
            ..Default::default()
        };
        let clean =
            run_cluster(ds.as_ref(), backend.clone(), &cfg).unwrap();
        let mut failing = cfg.clone();
        failing.failure =
            Some(FailurePlan { worker: 0, after_tasks: 2, on_attempt: 1 });
        let recovered = run_cluster_with_recovery(
            ds.as_ref(),
            backend.clone(),
            &failing,
            3,
        )
        .unwrap();
        assert_eq!(recovered.report.restarts, 1);
        assert_eq!(
            recovered.output, clean.output,
            "{w:?}: recovery changed the statistic"
        );
    }
}

#[test]
fn shutdown_is_orderly_and_accounted() {
    let backend = native();
    let ds = build_small(Workload::Eaglet, &params(), 25);
    let cfg = ExecConfig {
        sizing: TaskSizing::Tiniest,
        workers: 4,
        ..Default::default()
    };
    let r = run_cluster(ds.as_ref(), backend, &cfg).unwrap();
    // Every worker got an explicit Shutdown (no channel-death exits)…
    assert_eq!(r.workers.len(), 4);
    for ws in &r.workers {
        assert!(
            ws.clean_shutdown,
            "worker {} exited uncleanly: {ws:?}",
            ws.worker
        );
    }
    // …and together they executed every task exactly once.
    let executed: u64 = r.workers.iter().map(|w| w.executed).sum();
    assert_eq!(executed, r.report.tasks as u64);
    assert_eq!(r.report.tasks, 25); // tiniest = one task per sample
    // Overhead metrics were actually collected.
    assert!(r.overhead.dispatch_calls > 0);
    assert!(r.overhead.dispatch_s >= 0.0);
    assert!(r.overhead.queue_wait.n >= 1);
    // metrics record parses back as json
    let j = bts::util::json::Json::parse(
        &r.metrics_json().to_string_pretty(),
    )
    .unwrap();
    assert!(j.req("report").is_ok());
}

#[test]
fn injected_failure_fails_a_single_attempt() {
    let backend = native();
    let ds = build_small(Workload::Eaglet, &params(), 25);
    let mut cfg = ExecConfig {
        sizing: TaskSizing::Tiniest,
        workers: 3,
        ..Default::default()
    };
    cfg.failure =
        Some(FailurePlan { worker: 1, after_tasks: 2, on_attempt: 1 });
    let err = run_cluster(ds.as_ref(), backend, &cfg).unwrap_err();
    assert!(
        err.to_string().contains("injected node failure"),
        "unexpected error: {err}"
    );
}

#[test]
fn recovery_restarts_and_reproduces_the_clean_result() {
    let backend = native();
    let ds = build_small(Workload::Eaglet, &params(), 25);
    let cfg = ExecConfig {
        sizing: TaskSizing::Tiniest,
        workers: 3,
        ..Default::default()
    };
    let clean = run_cluster(ds.as_ref(), backend.clone(), &cfg).unwrap();
    let mut failing = cfg.clone();
    failing.failure =
        Some(FailurePlan { worker: 0, after_tasks: 2, on_attempt: 1 });
    let recovered =
        run_cluster_with_recovery(ds.as_ref(), backend, &failing, 3).unwrap();
    assert_eq!(recovered.report.restarts, 1, "exactly one restart");
    assert_eq!(
        recovered.output, clean.output,
        "job-level recovery must reproduce the statistic exactly"
    );
}

#[test]
fn persistent_failure_exhausts_attempts() {
    let backend = native();
    let ds = build_small(Workload::Eaglet, &params(), 12);
    let mut cfg = ExecConfig {
        sizing: TaskSizing::Tiniest,
        workers: 2,
        ..Default::default()
    };
    cfg.failure =
        Some(FailurePlan { worker: 0, after_tasks: 1, on_attempt: 1 });
    let err =
        run_cluster_with_recovery(ds.as_ref(), backend, &cfg, 1).unwrap_err();
    match err {
        Error::JobFailed { attempts, cause } => {
            assert_eq!(attempts, 1);
            assert!(cause.contains("injected"));
        }
        other => panic!("expected JobFailed, got {other}"),
    }
}

#[test]
fn large_sn_and_fixed_sizing_also_run() {
    // Multi-slice tasks (a BLT-style partition spans several compiled
    // buckets) flow through the same channel path.
    let backend = native();
    let ds = build_small(Workload::Eaglet, &params(), 30);
    for sizing in [
        TaskSizing::LargeSn { workers: 2 },
        TaskSizing::Fixed(64 * 1024),
    ] {
        let cfg = ExecConfig { sizing, workers: 2, ..Default::default() };
        let r = run_cluster(ds.as_ref(), backend.clone(), &cfg).unwrap();
        let JobOutput::Eaglet { weight, .. } = r.output else {
            panic!("wrong kind")
        };
        let chunks: f32 = ds.metas().iter().map(|m| m.units as f32).sum();
        assert!(
            (weight - chunks).abs() < 1e-2,
            "{sizing:?}: weight {weight} != {chunks}"
        );
    }
}
