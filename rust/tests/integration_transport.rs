//! Transport equivalence: the same job over in-proc channels and over
//! loopback TCP must produce bit-identical [`JobOutput`]s — the
//! determinism-across-transports contract (DESIGN.md §11). Native
//! kernel backend on both ends, so these run on every host.
//!
//! Covers both workload families, cache-on runs (leader-side shared
//! cache + worker-local cache), a mixed local+remote worker set,
//! worker-disconnect recovery on the solo executor, and the serve
//! pool with a remote map slot (including a mid-job disconnect that
//! tenant-scoped recovery absorbs).

use std::sync::Arc;
use std::thread;

use bts::data::{ModelParams, Workload};
use bts::exec::{
    run_cluster, run_cluster_with_recovery, Backend, ExecConfig,
};
use bts::kneepoint::TaskSizing;
use bts::net::run_worker;
use bts::serve::{
    JobRequest, JobService, PoolConfig, ServeConfig,
};
use bts::transport::{RemoteWorkerOpts, RemoteWorkers};
use bts::workloads::build_small;

// With `--features alloc-count` this binary owns the global allocator,
// so the warm-hit test below can assert the data plane's allocation
// contract. The counter is thread-local: concurrently running tests
// don't pollute the measurement window.
#[cfg(feature = "alloc-count")]
#[global_allocator]
static ALLOC: bts::util::alloc_counter::CountingAlloc =
    bts::util::alloc_counter::CountingAlloc;

fn native() -> Arc<Backend> {
    Arc::new(Backend::native(ModelParams::default()))
}

fn params() -> ModelParams {
    ModelParams::default()
}

const SIZING: TaskSizing = TaskSizing::Kneepoint(16 * 1024);
const SEED: u64 = 0xB75;

/// Spawn `n` remote worker sessions against `addr` on their own
/// threads; each runs the full `bts worker` path (connect with retry,
/// handshake, shared worker body over the DFS-proxied data plane).
fn spawn_workers(
    addr: String,
    n: usize,
    opts: RemoteWorkerOpts,
) -> Vec<thread::JoinHandle<u64>> {
    (0..n)
        .map(|_| {
            let addr = addr.clone();
            let opts = opts.clone();
            let backend = native();
            thread::spawn(move || {
                run_worker(&addr, backend, &opts).expect("worker session")
            })
        })
        .collect()
}

#[test]
fn tcp_runs_match_inproc_bit_for_bit_on_every_workload() {
    for workload in [
        Workload::Eaglet,
        Workload::NetflixLo,
        Workload::SeqAddr,
        Workload::Ssag,
    ] {
        let backend = native();
        let ds = build_small(workload, &params(), 36);
        let base = ExecConfig {
            sizing: SIZING,
            seed: SEED,
            ..Default::default()
        };

        // In-proc reference: 3 local slots.
        let reference = run_cluster(
            ds.as_ref(),
            backend.clone(),
            &ExecConfig { workers: 3, ..base.clone() },
        )
        .unwrap();

        // Mixed set: 1 local thread + 2 remote TCP workers.
        let remote = RemoteWorkers::bind("127.0.0.1:0", 2).unwrap();
        let addr = remote.addr();
        let workers =
            spawn_workers(addr, 2, RemoteWorkerOpts::default());
        let tcp = run_cluster(
            ds.as_ref(),
            backend,
            &ExecConfig { workers: 1, remote: Some(remote), ..base },
        )
        .unwrap();
        let executed_remote: u64 =
            workers.into_iter().map(|h| h.join().unwrap()).sum();

        assert_eq!(
            tcp.output, reference.output,
            "{workload:?}: TCP output differs from in-proc"
        );
        assert_eq!(tcp.report.tasks, reference.report.tasks);
        assert_eq!(tcp.workers.len(), 3, "1 local + 2 remote slots");
        assert!(
            tcp.workers.iter().all(|w| w.clean_shutdown),
            "every slot (remote included) exits via orderly Shutdown: {:?}",
            tcp.workers
        );
        let executed_total: u64 =
            tcp.workers.iter().map(|w| w.executed).sum();
        assert_eq!(executed_total, tcp.report.tasks as u64);
        assert!(
            executed_remote > 0,
            "{workload:?}: remote workers never executed anything"
        );
        // Remote fetches went through the leader's replicated store.
        assert!(tcp.dfs_bytes_served > 0);
    }
}

#[test]
fn caches_on_both_ends_leave_the_statistic_bit_identical() {
    let backend = native();
    let ds = build_small(Workload::Eaglet, &params(), 24);
    let base = ExecConfig {
        sizing: SIZING,
        seed: SEED,
        ..Default::default()
    };
    let plain = run_cluster(
        ds.as_ref(),
        backend.clone(),
        &ExecConfig { workers: 2, ..base.clone() },
    )
    .unwrap();

    // Leader-side shared cache + a worker-local cache in the remote.
    let remote = RemoteWorkers::bind("127.0.0.1:0", 1).unwrap();
    let addr = remote.addr();
    let workers = spawn_workers(
        addr,
        1,
        RemoteWorkerOpts { cache_mb: 8, ..Default::default() },
    );
    let cached = run_cluster(
        ds.as_ref(),
        backend,
        &ExecConfig {
            workers: 1,
            remote: Some(remote),
            cache_mb: 16,
            ..base
        },
    )
    .unwrap();
    for h in workers {
        h.join().unwrap();
    }
    assert_eq!(
        cached.output, plain.output,
        "caching (either end) must never change the statistic"
    );
    assert!(cached.cache.is_some(), "leader cache was attached");
}

/// Batched dispatch changes the wire shape only: the same job with
/// `TaskBatch` coalescing on and off must produce bit-identical
/// outputs, and the leader-side wire counters must show the frames
/// actually collapsing.
#[test]
fn batched_dispatch_is_bit_identical_to_unbatched_over_tcp() {
    let backend = native();
    let ds = build_small(Workload::Eaglet, &params(), 24);
    let mut results = Vec::new();
    for batch in [true, false] {
        let remote = RemoteWorkers::bind("127.0.0.1:0", 2).unwrap();
        let addr = remote.addr();
        let workers = spawn_workers(addr, 2, RemoteWorkerOpts::default());
        let r = run_cluster(
            ds.as_ref(),
            backend.clone(),
            &ExecConfig {
                sizing: TaskSizing::Tiniest,
                seed: SEED,
                workers: 0,
                remote: Some(remote),
                batch_dispatch: batch,
                ..Default::default()
            },
        )
        .unwrap();
        for h in workers {
            h.join().unwrap();
        }
        results.push(r);
    }
    let (batched, unbatched) = (&results[0], &results[1]);
    assert_eq!(
        batched.output, unbatched.output,
        "batching must never change the statistic"
    );
    assert!(
        batched.report.frames_batched > 0,
        "batched run never coalesced a refill window"
    );
    assert_eq!(
        unbatched.report.frames_batched, 0,
        "unbatched leader must not write TaskBatch frames"
    );
    assert!(
        batched.report.frames_sent < unbatched.report.frames_sent,
        "batching must collapse Down frames: {} (batched) vs {} \
         (unbatched)",
        batched.report.frames_sent,
        unbatched.report.frames_sent
    );
    assert!(batched.report.wire_bytes > 0, "wire counters not threaded");
}

/// The allocation half of the zero-copy contract: a warm cache-hit
/// block fetch is an index lookup, an intrusive-LRU touch, and an
/// `Arc` clone — zero heap allocations. Needs the counting allocator
/// installed, hence the feature gate.
#[cfg(feature = "alloc-count")]
#[test]
fn warm_cache_hit_block_fetch_allocates_nothing() {
    use bts::cache::BlockCache;
    use bts::util::alloc_counter;

    let cache = BlockCache::new(1 << 20, 2);
    let data = Arc::new(vec![42u8; 8192]);
    cache.insert("t/acme/blk:0", &data);
    // First hit promotes probation → protected; the contract under
    // test is the steady warm state after it.
    drop(cache.get("t/acme/blk:0").expect("resident"));

    alloc_counter::reset();
    let hit = cache.get("t/acme/blk:0").expect("warm hit");
    let n = alloc_counter::allocations();
    assert_eq!(
        n, 0,
        "warm cache-hit fetch allocated {n} times; expected none"
    );
    assert_eq!(hit.len(), 8192);
}

#[test]
fn dropped_tcp_worker_recovers_deterministically() {
    let backend = native();
    let ds = build_small(Workload::Eaglet, &params(), 24);
    let reference = run_cluster(
        ds.as_ref(),
        backend.clone(),
        &ExecConfig {
            sizing: TaskSizing::Tiniest,
            seed: SEED,
            workers: 2,
            ..Default::default()
        },
    )
    .unwrap();

    let remote = RemoteWorkers::bind("127.0.0.1:0", 1).unwrap();
    let addr = remote.addr();
    // Worker supplier: session 1 drops the link after one completion
    // (a crashed worker, no goodbye); session 2 reconnects clean for
    // the recovery attempt. The dropping worker is the *only* map
    // slot, so attempt 1 cannot complete without it — the failure is
    // deterministic, not a race against faster neighbours.
    let supplier = thread::spawn({
        let addr = addr.clone();
        move || {
            let _ = run_worker(
                &addr,
                native(),
                &RemoteWorkerOpts {
                    drop_link_after: Some(1),
                    ..Default::default()
                },
            );
            run_worker(&addr, native(), &RemoteWorkerOpts::default())
                .expect("replacement worker session")
        }
    });
    let recovered = run_cluster_with_recovery(
        ds.as_ref(),
        backend,
        &ExecConfig {
            sizing: TaskSizing::Tiniest,
            seed: SEED,
            workers: 0,
            remote: Some(remote),
            ..Default::default()
        },
        3,
    )
    .unwrap();
    supplier.join().unwrap();
    assert_eq!(
        recovered.report.restarts, 1,
        "the dropped link must fail exactly one attempt"
    );
    assert_eq!(
        recovered.output, reference.output,
        "recovery after a dropped TCP worker must reproduce the statistic"
    );
}

/// Regression for the remote data plane's failure path: a worker that
/// requests a block and then severs the connection *mid-`DfsBlock`
/// transfer* (a few bytes into the reply) must surface as a lost
/// worker, fail exactly one attempt, and recover bit-identically —
/// the leader must neither panic in the link pump nor hang waiting
/// for the half-read reply to be acknowledged.
#[test]
fn mid_dfs_block_disconnect_recovers_and_never_hangs() {
    use std::io::Read;
    use std::net::TcpStream;

    use bts::net::protocol::Message;

    let backend = native();
    let ds = build_small(Workload::Eaglet, &params(), 24);
    let reference = run_cluster(
        ds.as_ref(),
        backend.clone(),
        &ExecConfig {
            sizing: TaskSizing::Tiniest,
            seed: SEED,
            workers: 2,
            ..Default::default()
        },
    )
    .unwrap();

    let remote = RemoteWorkers::bind("127.0.0.1:0", 1).unwrap();
    let addr = remote.addr();
    let saboteur = thread::spawn({
        let addr = addr.clone();
        move || {
            // One raw frame off the wire: header, then payload.
            fn read_frame(stream: &mut TcpStream) -> Vec<u8> {
                let mut header = [0u8; 8];
                stream.read_exact(&mut header).unwrap();
                let len = u32::from_le_bytes(
                    header[4..8].try_into().unwrap(),
                ) as usize;
                let mut payload = vec![0u8; len];
                stream.read_exact(&mut payload).unwrap();
                payload
            }

            // A hand-rolled worker session: handshake, fetch one block
            // cleanly (skipping the task dispatches the leader pushes
            // first), then request a second block and sever the socket
            // with its DfsBlock reply half-read.
            let mut stream = TcpStream::connect(&addr).unwrap();
            Message::Hello { worker: 0 }.write_to(&mut stream).unwrap();
            match Message::decode(&read_frame(&mut stream)).unwrap() {
                Message::Welcome { .. } => {}
                other => panic!("expected Welcome, got {other:?}"),
            }
            let key =
                bts::data::block::block_key("", Workload::Eaglet, 0);
            Message::DfsGet { key }.write_to(&mut stream).unwrap();
            loop {
                match Message::decode(&read_frame(&mut stream)).unwrap()
                {
                    Message::DfsBlock { .. } => break,
                    Message::DfsMiss { key, message } => {
                        panic!("miss for {key}: {message}")
                    }
                    _ => {} // task dispatches — never acked
                }
            }
            // Second fetch: this reply is the frame we cut in half.
            let key =
                bts::data::block::block_key("", Workload::Eaglet, 1);
            Message::DfsGet { key }.write_to(&mut stream).unwrap();
            let mut header = [0u8; 8];
            stream.read_exact(&mut header).unwrap();
            let len =
                u32::from_le_bytes(header[4..8].try_into().unwrap())
                    as usize;
            let mut half = vec![0u8; len / 2];
            stream.read_exact(&mut half).unwrap();
            drop(stream);
            // Clean replacement for the recovery attempt.
            run_worker(&addr, native(), &RemoteWorkerOpts::default())
                .expect("replacement worker session")
        }
    });
    // The saboteur is the only map slot, so attempt 1 deterministically
    // dies with it; attempt 2 adopts the replacement.
    let recovered = run_cluster_with_recovery(
        ds.as_ref(),
        backend,
        &ExecConfig {
            sizing: TaskSizing::Tiniest,
            seed: SEED,
            workers: 0,
            remote: Some(remote),
            ..Default::default()
        },
        3,
    )
    .unwrap();
    let replacement_executed = saboteur.join().unwrap();
    assert!(replacement_executed > 0, "replacement never ran a task");
    assert_eq!(
        recovered.report.restarts, 1,
        "the mid-transfer disconnect must cost exactly one attempt"
    );
    assert_eq!(
        recovered.output, reference.output,
        "recovery after a mid-DfsBlock disconnect diverged"
    );
}

/// The serve-layer halves: a remote pool slot multiplexing tenants,
/// and tenant-scoped recovery absorbing a mid-job disconnect.
#[test]
fn serve_pool_with_remote_slot_matches_solo_run() {
    let backend = native();
    // Solo oracle for the same (workload, samples, sizing, seed).
    let ds = build_small(Workload::Eaglet, &params(), 20);
    let solo = run_cluster(
        ds.as_ref(),
        backend.clone(),
        &ExecConfig {
            sizing: SIZING,
            seed: SEED,
            workers: 2,
            ..Default::default()
        },
    )
    .unwrap();

    let remote = RemoteWorkers::bind("127.0.0.1:0", 1).unwrap();
    let addr = remote.addr();
    let workers = spawn_workers(addr, 1, RemoteWorkerOpts::default());
    let svc = JobService::start(
        backend,
        ServeConfig {
            pool: PoolConfig {
                workers: 1,
                remote: Some(remote),
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap();
    let req = JobRequest::new(Workload::Eaglet, 20)
        .with_seed(SEED)
        .with_sizing(SIZING);
    let r1 = svc.submit(req.clone()).unwrap().wait().unwrap();
    let r2 = svc.submit(req).unwrap().wait().unwrap();
    let report = svc.shutdown().unwrap();
    for h in workers {
        h.join().unwrap();
    }
    assert_eq!(r1.output, solo.output, "served ≠ solo");
    assert_eq!(r2.output, solo.output, "second tenant ≠ solo");
    assert_eq!(report.jobs_completed, 2);
    assert_eq!(report.workers, 2, "1 local + 1 remote slot");
    assert_eq!(report.workers_spawned, 2, "warm pool, no respawns");
}

#[test]
fn serve_survives_remote_slot_disconnect_with_tenant_recovery() {
    let backend = native();
    let ds = build_small(Workload::Eaglet, &params(), 20);
    let solo = run_cluster(
        ds.as_ref(),
        backend.clone(),
        &ExecConfig {
            sizing: TaskSizing::Tiniest,
            seed: SEED,
            workers: 2,
            ..Default::default()
        },
    )
    .unwrap();

    let remote = RemoteWorkers::bind("127.0.0.1:0", 1).unwrap();
    let addr = remote.addr();
    // This slot crashes after one completed task and never comes
    // back; the pool has no respawn path, so the session finishes on
    // the local slot alone. The sleeping latency model paces the
    // local slot (~1ms per fetch), so the remote slot reliably holds
    // dispatched work when it vanishes.
    let workers = spawn_workers(
        addr,
        1,
        RemoteWorkerOpts { drop_link_after: Some(1), ..Default::default() },
    );
    let svc = JobService::start(
        backend,
        ServeConfig {
            pool: PoolConfig {
                workers: 1,
                remote: Some(remote),
                latency: bts::dfs::LatencyModel {
                    base_s: 1e-3,
                    per_mib_s: 0.0,
                    per_inflight_s: 0.0,
                    sleep: true,
                },
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap();
    let req = JobRequest::new(Workload::Eaglet, 20)
        .with_seed(SEED)
        .with_sizing(TaskSizing::Tiniest);
    let r = svc.submit(req).unwrap().wait().unwrap();
    let report = svc.shutdown().unwrap();
    for h in workers {
        let _ = h.join();
    }
    assert_eq!(
        r.output, solo.output,
        "tenant-scoped recovery after a lost slot must reproduce the \
         statistic"
    );
    assert!(
        r.report.restarts >= 1,
        "the lost slot must have forced at least one restart"
    );
    assert_eq!(report.jobs_completed, 1);
    assert_eq!(report.jobs_failed, 0, "the tenant must not be failed");
}
