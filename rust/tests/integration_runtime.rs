//! Runtime ↔ artifact round-trips: compiled HLO executes correctly and
//! the artifact-based reduce tree matches a host-side f64 oracle.
//! Needs `make artifacts`.

use std::sync::Arc;

use bts::coordinator::{finalize_netflix, reduce_eaglet, reduce_netflix};
use bts::runtime::{HostTensor, Manifest, Runtime};
use bts::util::rng::Rng;

fn runtime() -> Option<(Arc<Manifest>, Runtime)> {
    let m = match Manifest::load("artifacts") {
        Ok(m) => Arc::new(m),
        Err(_) => {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
    };
    let rt = Runtime::new(m.clone()).unwrap();
    Some((m, rt))
}

#[test]
fn every_manifest_entry_compiles_and_executes() {
    let Some((m, rt)) = runtime() else { return };
    let mut rng = Rng::new(0xC0FFEE);
    for e in &m.entries {
        let inputs: Vec<HostTensor> = e
            .inputs
            .iter()
            .map(|spec| {
                let n = spec.elements();
                match spec.dtype {
                    bts::runtime::Dtype::F32 => HostTensor::F32(
                        (0..n).map(|_| rng.f32()).collect(),
                        spec.shape.clone(),
                    ),
                    bts::runtime::Dtype::I32 => {
                        // index inputs must stay in their gather range;
                        // every idx input indexes either markers or
                        // the ratings cap — both ≥ 16, so stay under 16.
                        HostTensor::I32(
                            (0..n).map(|_| rng.below(16) as i32).collect(),
                            spec.shape.clone(),
                        )
                    }
                }
            })
            .collect();
        let out = rt.execute(e, &inputs).unwrap_or_else(|err| {
            panic!("{} failed to execute: {err}", e.name)
        });
        assert_eq!(out.len(), e.outputs.len(), "{}: output arity", e.name);
        for (o, spec) in out.iter().zip(&e.outputs) {
            assert_eq!(o.len(), spec.elements(), "{}: output size", e.name);
            assert!(
                o.iter().all(|v| v.is_finite()),
                "{}: non-finite output",
                e.name
            );
        }
    }
    // compile cache: all entries compiled exactly once
    assert_eq!(rt.compiled_count(), m.entries.len());
}

#[test]
fn eaglet_reduce_tree_matches_f64_oracle() {
    let Some((m, rt)) = runtime() else { return };
    let p = &m.params;
    let mut rng = Rng::new(7);
    // 100 partials forces two tree levels at fan-in 16.
    let partials: Vec<(Vec<f32>, f32)> = (0..100)
        .map(|_| {
            let alod: Vec<f32> =
                (0..p.grid).map(|_| rng.f32() * 4.0 - 2.0).collect();
            let w = 1.0 + rng.below(20) as f32;
            (alod, w)
        })
        .collect();
    let mut wsum = vec![0.0f64; p.grid];
    let mut wtot = 0.0f64;
    for (alod, w) in &partials {
        for (acc, v) in wsum.iter_mut().zip(alod) {
            *acc += *v as f64 * *w as f64;
        }
        wtot += *w as f64;
    }
    let (alod, weight) = reduce_eaglet(&rt, p, partials).unwrap();
    assert!((weight as f64 - wtot).abs() < 1e-2);
    for (i, (got, want)) in
        alod.iter().zip(wsum.iter().map(|v| v / wtot)).enumerate()
    {
        assert!(
            (*got as f64 - want).abs() < 1e-3,
            "grid {i}: {got} vs {want}"
        );
    }
}

#[test]
fn netflix_reduce_tree_matches_f64_oracle() {
    let Some((m, rt)) = runtime() else { return };
    let p = &m.params;
    let f = p.months * p.stat_fields;
    let mut rng = Rng::new(8);
    let partials: Vec<Vec<f32>> = (0..50)
        .map(|_| (0..f).map(|_| rng.f32() * 10.0).collect())
        .collect();
    let mut want = vec![0.0f64; f];
    for part in &partials {
        for (acc, v) in want.iter_mut().zip(part) {
            *acc += *v as f64;
        }
    }
    let got = reduce_netflix(&rt, p, partials).unwrap();
    for i in 0..f {
        assert!(
            (got[i] as f64 - want[i]).abs() < want[i].abs() * 1e-4 + 1e-3,
            "field {i}: {} vs {}",
            got[i],
            want[i]
        );
    }
}

#[test]
fn single_partial_reduces_are_identity() {
    let Some((m, rt)) = runtime() else { return };
    let p = &m.params;
    let alod: Vec<f32> = (0..p.grid).map(|i| i as f32).collect();
    let (out, w) = reduce_eaglet(&rt, p, vec![(alod.clone(), 3.0)]).unwrap();
    assert_eq!(out, alod);
    assert_eq!(w, 3.0);
    let stats: Vec<f32> =
        (0..p.months * p.stat_fields).map(|i| i as f32).collect();
    let out = reduce_netflix(&rt, p, vec![stats.clone()]).unwrap();
    assert_eq!(out, stats);
}

#[test]
fn finalize_after_reduce_produces_valid_ci() {
    let Some((m, rt)) = runtime() else { return };
    let p = &m.params;
    let f = p.stat_fields;
    // two partials, month 0: ratings {2,4} and {3,5}
    let mk = |sum: f32, sumsq: f32, n: f32| {
        let mut v = vec![0.0f32; p.months * f];
        v[0] = sum;
        v[1] = sumsq;
        v[2] = n;
        v
    };
    let parts = vec![mk(6.0, 20.0, 2.0), mk(8.0, 34.0, 2.0)];
    let reduced = reduce_netflix(&rt, p, parts).unwrap();
    let stats = finalize_netflix(p, &reduced).unwrap();
    assert!((stats.mean[0] - 3.5).abs() < 1e-6);
    assert_eq!(stats.count[0], 4.0);
    assert!(stats.ci_half[0] > 0.0);
}

#[test]
fn warm_precompiles_entries() {
    let Some((m, rt)) = runtime() else { return };
    assert_eq!(rt.compiled_count(), 0);
    rt.warm(&["eaglet_map_b1", "netflix_reduce"]).unwrap();
    assert_eq!(rt.compiled_count(), 2);
    assert!(rt.warm(&["nonexistent"]).is_err());
    let _ = m;
}
