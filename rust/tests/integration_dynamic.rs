//! Response-time-aware dynamic scheduling + speculative re-execution,
//! end to end (DESIGN.md §12): the statistic is bit-identical with
//! speculation on vs off (both workloads, in-proc and loopback TCP),
//! stragglers are cloned at most once and dead clones are cleaned up
//! after the winner lands, and the injected-slow-worker tail actually
//! improves. Native backend throughout — no artifacts needed.
//!
//! Slow workers are scripted with the deterministic
//! [`Turbulence`] injector: the delay lands *outside* the worker's own
//! timers (modelled node contention), so only the leader-observed
//! response times can catch it — which is the point of the tracker.

use std::sync::Arc;
use std::thread;
use std::time::Duration;

use bts::data::{ModelParams, Workload};
use bts::exec::{run_cluster, Backend, ExecConfig, ExecResult};
use bts::kneepoint::TaskSizing;
use bts::net::run_worker;
use bts::scheduler::SchedConfig;
use bts::serve::{JobRequest, JobService, PoolConfig, ServeConfig};
use bts::transport::{RemoteWorkerOpts, RemoteWorkers};
use bts::util::testutil::{Turbulence, SERVE_JOB_DEADLINE};
use bts::workloads::build_small;

const SIZING: TaskSizing = TaskSizing::Tiniest;
const SEED: u64 = 0xD1A;
/// The scripted straggler delay: large enough to dwarf debug-build
/// task times by an order of magnitude, so tail assertions have slack.
const SLOW: Duration = Duration::from_millis(150);

fn native() -> Arc<Backend> {
    Arc::new(Backend::native(ModelParams::default()))
}

fn sched(speculate: bool) -> SchedConfig {
    SchedConfig {
        dynamic: speculate,
        speculate,
        straggler_pct: 95.0,
        ..Default::default()
    }
}

/// Three local slots, slot 2 slowed by `SLOW` per task from its first
/// task onward.
fn turbulent_cfg(speculate: bool) -> ExecConfig {
    ExecConfig {
        sizing: SIZING,
        workers: 3,
        seed: SEED,
        sched: sched(speculate),
        turbulence: Some(Arc::new(Turbulence::new(SEED).slow_from(2, 0, SLOW))),
        ..Default::default()
    }
}

fn run(workload: Workload, samples: usize, cfg: &ExecConfig) -> ExecResult {
    let ds = build_small(workload, &ModelParams::default(), samples);
    run_cluster(ds.as_ref(), native(), cfg).unwrap()
}

#[test]
fn speculation_is_bit_identical_on_both_workloads_in_proc() {
    for workload in [Workload::Eaglet, Workload::NetflixHi] {
        let off = run(workload, 30, &turbulent_cfg(false));
        let on = run(workload, 30, &turbulent_cfg(true));
        assert_eq!(
            on.output, off.output,
            "{workload:?}: speculation changed the statistic"
        );
        assert_eq!(on.report.tasks, off.report.tasks);
        // the injected straggler was detected and cloned...
        assert!(
            on.sched.speculated >= 1,
            "{workload:?}: no speculation despite a 150ms straggler: {:?}",
            on.sched
        );
        // ...and a clone beat the stuck original at least once
        assert!(
            on.sched.won_by_clone >= 1,
            "{workload:?}: clones never won: {:?}",
            on.sched
        );
        assert!(on.sched.won_by_clone <= on.sched.speculated);
        // baseline two-step never speculates
        assert_eq!(off.sched.speculated, 0);
        assert_eq!(off.sched.won_by_clone, 0);
    }
}

#[test]
fn stragglers_clone_at_most_once_and_dead_clones_are_reclaimed() {
    let on = run(Workload::Eaglet, 30, &turbulent_cfg(true));
    let tasks = on.report.tasks as u64;
    // exactly-once speculation: every clone is one extra dispatch at
    // most, so total executions can exceed the task count only by the
    // number of speculated tasks (abandoned queued clones execute
    // zero times — that is the dead-clone cleanup)
    let executed: u64 = on.workers.iter().map(|w| w.executed).sum();
    assert!(executed >= tasks, "{executed} executions < {tasks} tasks");
    assert!(
        executed - tasks <= on.sched.speculated,
        "{} duplicate executions but only {} speculations — some task \
         was cloned more than once",
        executed - tasks,
        on.sched.speculated
    );
    assert!(on.sched.speculated <= tasks);
    // the early-release path still shuts every slot down cleanly (the
    // straggling slot abandons its dead clones at the Shutdown marker
    // rather than draining them)
    assert!(
        on.workers.iter().all(|w| w.clean_shutdown),
        "unclean shutdown: {:?}",
        on.workers
    );
}

#[test]
fn dynamic_speculation_beats_twostep_tail_under_a_slow_worker() {
    let off = run(Workload::Eaglet, 30, &turbulent_cfg(false));
    let on = run(Workload::Eaglet, 30, &turbulent_cfg(true));
    assert_eq!(on.output, off.output);
    let (off_p99, on_p99) =
        (off.report.task_turnaround.p99, on.report.task_turnaround.p99);
    // The baseline strands a dispatch window on the slow slot, so its
    // p99 turnaround stacks several 150ms tasks; speculation caps a
    // straggler's turnaround at roughly detection + one fast clone.
    // The bench asserts the full 2x bar in release; here (debug, CI
    // noise) we still demand a decisive improvement.
    assert!(
        on_p99 * 1.5 < off_p99,
        "tail not improved: on p99 {:.1}ms vs off p99 {:.1}ms",
        on_p99 * 1e3,
        off_p99 * 1e3
    );
    assert!(
        on.report.map_s < off.report.map_s,
        "job wall not improved: on {:.1}ms vs off {:.1}ms",
        on.report.map_s * 1e3,
        off.report.map_s * 1e3
    );
}

#[test]
fn speculation_is_bit_identical_over_loopback_tcp() {
    for workload in [
        Workload::Eaglet,
        Workload::NetflixLo,
        Workload::SeqAddr,
        Workload::Ssag,
    ] {
        // In-proc, speculation off: the oracle.
        let reference = run(
            workload,
            24,
            &ExecConfig {
                sizing: SIZING,
                workers: 2,
                seed: SEED,
                ..Default::default()
            },
        );
        // Mixed local+remote with dynamic scheduling + speculation on
        // (the remote link's heartbeat feeds the same tracker).
        let remote = RemoteWorkers::bind("127.0.0.1:0", 1).unwrap();
        let addr = remote.addr();
        let worker = thread::spawn({
            let addr = addr.clone();
            move || {
                run_worker(&addr, native(), &RemoteWorkerOpts::default())
                    .expect("worker session")
            }
        });
        let ds = build_small(workload, &ModelParams::default(), 24);
        let tcp = run_cluster(
            ds.as_ref(),
            native(),
            &ExecConfig {
                sizing: SIZING,
                workers: 1,
                remote: Some(remote),
                seed: SEED,
                sched: sched(true),
                ..Default::default()
            },
        )
        .unwrap();
        worker.join().unwrap();
        assert_eq!(
            tcp.output, reference.output,
            "{workload:?}: TCP + speculation diverged from the in-proc \
             oracle"
        );
        assert!(
            tcp.workers.iter().all(|w| w.clean_shutdown),
            "{workload:?}: unclean shutdown: {:?}",
            tcp.workers
        );
    }
}

#[test]
fn serve_pool_speculates_and_keeps_tenants_bit_identical() {
    // Solo oracles (no turbulence, no speculation).
    let solo = |workload: Workload, seed: u64| {
        run(
            workload,
            24,
            &ExecConfig {
                sizing: SIZING,
                workers: 2,
                seed,
                ..Default::default()
            },
        )
        .output
    };
    let svc = JobService::start(
        native(),
        ServeConfig {
            pool: PoolConfig {
                workers: 3,
                turbulence: Some(Arc::new(
                    Turbulence::new(SEED).slow_from(2, 0, SLOW),
                )),
                ..Default::default()
            },
            max_active: 2,
            sched: sched(true),
            ..Default::default()
        },
    )
    .unwrap();
    let req = |workload: Workload, seed: u64| {
        JobRequest::new(workload, 24)
            .with_seed(seed)
            .with_sizing(SIZING)
    };
    let ha = svc.submit(req(Workload::Eaglet, 41)).unwrap();
    let hb = svc.submit(req(Workload::NetflixHi, 42)).unwrap();
    let ra = ha.wait_timeout(SERVE_JOB_DEADLINE).unwrap();
    let rb = hb.wait_timeout(SERVE_JOB_DEADLINE).unwrap();
    assert_eq!(ra.output, solo(Workload::Eaglet, 41), "tenant A diverged");
    assert_eq!(
        rb.output,
        solo(Workload::NetflixHi, 42),
        "tenant B diverged"
    );
    let report = svc.shutdown().unwrap();
    assert_eq!(report.jobs_completed, 2);
    assert_eq!(report.jobs_failed, 0);
    assert_eq!(report.worker_respawns(), 0);
    // the slow pool slot forced at least one clone across the session,
    // and per-job counters surfaced into the tenants' reports too
    assert!(
        report.speculated >= 1,
        "pool never speculated despite a 150ms slot: {report:?}"
    );
    assert_eq!(
        ra.report.speculated + rb.report.speculated,
        report.speculated
    );
    assert!(report.won_by_clone <= report.speculated);
}
