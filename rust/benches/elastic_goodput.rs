//! Elastic membership vs restart-based recovery under a seeded
//! kill/add schedule (DESIGN.md §14).
//!
//!     cargo bench --bench elastic_goodput
//!
//! Both arms run the same job through the same deterministic
//! [`Turbulence`]: worker 2 crashes (unclean exit, no goodbye) at its
//! 20th task. The restart arm is the historical semantics — the loss
//! aborts the attempt and `run_cluster_with_recovery` replays the
//! whole job. The elastic arm absorbs the loss live: the membership
//! ledger re-dispatches only the dead slot's in-flight window, the
//! survivors keep going, and a late `bts worker --connect` joins
//! mid-job to replace the lost capacity.
//!
//! The headline metric is goodput — distinct completed tiny tasks per
//! wall-clock second, failed-attempt time included — written to
//! `results/BENCH_elastic.json`. The run asserts the thesis-level
//! claims: identical statistics on every arm, zero restarts on the
//! elastic arm, re-dispatch bounded by the lost in-flight window (not
//! the whole job), and elastic goodput at or above the restart
//! baseline's.

use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use bts::data::{ModelParams, Workload};
use bts::dfs::LatencyModel;
use bts::exec::{
    run_cluster, run_cluster_with_recovery, Backend, ExecConfig,
    ExecResult,
};
use bts::kneepoint::TaskSizing;
use bts::net::run_worker;
use bts::transport::{RemoteWorkerOpts, RemoteWorkers};
use bts::util::bench::Bench;
use bts::util::json::{num, obj, s, Json};
use bts::util::testutil::Turbulence;
use bts::workloads::build_small;

const WORKERS: usize = 4;
const KILLED_WORKER: usize = 2;
const KILL_AT_TASK: u64 = 20;
const SAMPLES: usize = 160;
const SEED: u64 = 0xB75;
const ITERS: usize = 3;

fn native() -> Arc<Backend> {
    Arc::new(Backend::native(ModelParams::default()))
}

/// Base config shared by both arms: tiny tasks over a data plane with
/// a real (slept) per-fetch latency, so wall-clock goodput measures
/// pipeline behaviour rather than pure in-memory dispatch.
fn base_cfg() -> ExecConfig {
    ExecConfig {
        sizing: TaskSizing::Tiniest,
        workers: WORKERS,
        seed: SEED,
        latency: LatencyModel {
            base_s: 1e-3,
            per_mib_s: 0.0,
            per_inflight_s: 0.0,
            sleep: true,
        },
        ..Default::default()
    }
}

/// Each run arms a fresh kill — the rule fires once per Turbulence
/// instance, which is exactly what the restart arm needs (attempt 2
/// replays clean) but would leave later iterations undisturbed.
fn kill_schedule() -> Arc<Turbulence> {
    Arc::new(Turbulence::new(SEED).kill_at(KILLED_WORKER, KILL_AT_TASK))
}

struct Arm {
    result: ExecResult,
    wall_s: f64,
}

/// Restart-based recovery (the historical baseline): the kill aborts
/// attempt 1, attempt 2 replays the whole job.
fn run_restart(backend: &Arc<Backend>) -> Arm {
    let ds = build_small(Workload::Eaglet, &ModelParams::default(), SAMPLES);
    let cfg = ExecConfig {
        turbulence: Some(kill_schedule()),
        ..base_cfg()
    };
    let t = Instant::now();
    let result =
        run_cluster_with_recovery(ds.as_ref(), backend.clone(), &cfg, 3)
            .expect("restart arm");
    Arm { result, wall_s: t.elapsed().as_secs_f64() }
}

/// Elastic absorption: the same kill is a ledger re-dispatch, and a
/// late TCP joiner replaces the lost slot mid-job.
fn run_elastic(backend: &Arc<Backend>) -> Arm {
    let ds = build_small(Workload::Eaglet, &ModelParams::default(), SAMPLES);
    let remote = RemoteWorkers::bind("127.0.0.1:0", 0).expect("bind");
    let addr = remote.addr();
    let joiner = thread::spawn(move || {
        thread::sleep(Duration::from_millis(5));
        run_worker(&addr, native(), &RemoteWorkerOpts::default())
    });
    let cfg = ExecConfig {
        elastic: true,
        remote: Some(remote),
        turbulence: Some(kill_schedule()),
        ..base_cfg()
    };
    let t = Instant::now();
    let result =
        run_cluster(ds.as_ref(), backend.clone(), &cfg).expect("elastic arm");
    let wall_s = t.elapsed().as_secs_f64();
    joiner
        .join()
        .unwrap()
        .expect("the mid-job joiner must be admitted");
    Arm { result, wall_s }
}

fn goodput(arm: &Arm) -> f64 {
    arm.result.report.tasks as f64 / arm.wall_s.max(1e-9)
}

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

fn record(mode: &str, arm: &Arm) -> Json {
    obj(vec![
        ("label", s(mode)),
        ("tasks", num(arm.result.report.tasks as f64)),
        ("wall_s", num(arm.wall_s)),
        ("goodput_tasks_per_s", num(goodput(arm))),
        ("restarts", num(arm.result.report.restarts as f64)),
        ("re_dispatched", num(arm.result.re_dispatched as f64)),
    ])
}

fn main() {
    let backend = native();
    let mut b = Bench::new("elastic_goodput");
    let inflight_window = base_cfg().inflight as u64;

    let mut records = Vec::new();
    let mut restart_goodput = Vec::new();
    let mut elastic_goodput = Vec::new();
    let mut outputs = Vec::new();

    for i in 0..ITERS {
        let restart = run_restart(&backend);
        let elastic = run_elastic(&backend);
        assert_eq!(
            restart.result.output, elastic.result.output,
            "recovery strategy changed the statistic"
        );
        assert_eq!(
            restart.result.report.restarts, 1,
            "the kill must cost the restart arm exactly one attempt"
        );
        assert_eq!(
            elastic.result.report.restarts, 0,
            "the elastic arm must absorb the kill without restarting"
        );
        assert!(
            elastic.result.re_dispatched >= 1,
            "the dead slot held in-flight work; the ledger must \
             re-dispatch it"
        );
        assert!(
            elastic.result.re_dispatched <= inflight_window,
            "re-executed {} tasks — more than the lost slot's \
             in-flight window of {} (whole-job re-execution?)",
            elastic.result.re_dispatched,
            inflight_window
        );
        restart_goodput.push(goodput(&restart));
        elastic_goodput.push(goodput(&elastic));
        if i == 0 {
            records.push(record("restart_recovery", &restart));
            records.push(record("elastic_ledger", &elastic));
        }
        outputs.push(elastic.result.output);
    }
    assert!(
        outputs.windows(2).all(|w| w[0] == w[1]),
        "elastic runs must be deterministic across repeats"
    );

    let restart_med = median(restart_goodput);
    let elastic_med = median(elastic_goodput);
    let ratio = elastic_med / restart_med.max(1e-9);
    b.record("restart_goodput", restart_med, "tasks/s");
    b.record("elastic_goodput", elastic_med, "tasks/s");
    b.record("goodput_ratio", ratio, "x");
    records.push(obj(vec![
        ("label", s("ratio")),
        ("restart_goodput_tasks_per_s", num(restart_med)),
        ("elastic_goodput_tasks_per_s", num(elastic_med)),
        ("goodput_ratio", num(ratio)),
    ]));

    let path = bts::util::bench_record::write("elastic", records)
        .expect("write BENCH_elastic.json");
    println!("wrote {path}");
    b.finish();

    // The acceptance bar: task-level checkpointing must beat paying a
    // whole extra attempt. The restart arm replays every tiny task;
    // the elastic arm re-executes at most one in-flight window.
    assert!(
        ratio >= 1.0,
        "elastic goodput ({elastic_med:.1} tasks/s) fell below the \
         restart baseline ({restart_med:.1} tasks/s)"
    );
}
