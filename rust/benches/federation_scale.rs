//! Federation scaling, shedding, and many-tenant placement
//! (DESIGN.md §15).
//!
//!     cargo bench --bench federation_scale
//!
//! Three segments, all written to `results/BENCH_federation.json`:
//!
//! * **scaling** — the identical 24-job mixed-tenant set through a
//!   1-leader and a 2-leader federation of the same per-shard shape.
//!   Each shard runs one job at a time, so the serial chain halves
//!   when a second leader joins; the run asserts ≥ 1.6x wall-clock
//!   speedup at an unchanged SLO-miss rate, and that fleet size never
//!   changes a single statistic (the determinism contract).
//! * **overload** — a 40-job burst into a backlog cap of 4: the
//!   front-door must shed the overflow fast with positive Retry-After
//!   hints instead of queueing it, then drain what it admitted.
//! * **tenant_spread** — thousands of synthetic tenants over the
//!   placement ring (Jain-balanced shards) and a 2048-tenant DRF
//!   allocation against a 256-slot federation.

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;
use std::time::Instant;

use bts::coordinator::JobOutput;
use bts::data::{ModelParams, Workload};
use bts::dfs::Ring;
use bts::error::Error;
use bts::exec::Backend;
use bts::federation::{
    allocate, Capacity, Demand, Federation, FederationConfig, TenantDemand,
};
use bts::metrics::{jain_index, FederationReport};
use bts::serve::JobRequest;
use bts::util::bench::Bench;
use bts::util::json::{num, obj, s, Json};
use bts::util::rng::Rng;
use bts::util::testutil::SERVE_JOB_DEADLINE;

const SCALE_JOBS: usize = 24;
const SCALE_TENANTS: usize = 12;
const SCALE_SAMPLES: usize = 32;
const BURST_JOBS: u64 = 40;
const BURST_CAP: usize = 4;
const RING_TENANTS: usize = 4096;
const DRF_TENANTS: usize = 2048;

fn native() -> Arc<Backend> {
    Arc::new(Backend::native(ModelParams::default()))
}

/// One-shard-shape config: every leader runs one job at a time, so
/// adding leaders is the *only* source of concurrency and the scaling
/// segment measures exactly the front-door's fan-out.
fn scale_cfg(leaders: usize) -> FederationConfig {
    FederationConfig {
        leaders,
        workers_per_leader: 1,
        max_active_per_leader: 1,
        leader_outstanding_cap: 1,
        backlog_cap: 1024,
        ..FederationConfig::default()
    }
}

struct ScaleArm {
    report: FederationReport,
    wall_s: f64,
    /// seed → statistic, for the cross-arm determinism check.
    outputs: BTreeMap<u64, JobOutput>,
}

fn run_scale_arm(leaders: usize) -> ScaleArm {
    let mut fed = Federation::start(native(), scale_cfg(leaders))
        .expect("start federation");
    let mut seed_of: HashMap<u64, u64> = HashMap::new();
    let t = Instant::now();
    for j in 0..SCALE_JOBS {
        let tenant = format!("tenant-{:02}", j % SCALE_TENANTS);
        let seed = 0xFED5_0000 + j as u64;
        let req = JobRequest::new(Workload::Eaglet, SCALE_SAMPLES)
            .with_seed(seed)
            // generous but real: every job passes the same admission
            // gate, so both arms report a comparable SLO-miss rate
            .with_deadline(1e6);
        let id = fed.submit(&tenant, req).expect("admit scale job");
        seed_of.insert(id, seed);
    }
    fed.pump_until_idle(SERVE_JOB_DEADLINE).expect("drain scale arm");
    let wall_s = t.elapsed().as_secs_f64();
    let done = fed.drain_completions();
    assert_eq!(done.len(), SCALE_JOBS);
    let mut outputs = BTreeMap::new();
    for c in done {
        let res = c.result.expect("scale job");
        outputs.insert(seed_of[&c.id], res.output);
    }
    let report = fed.shutdown().expect("shutdown scale arm");
    ScaleArm { report, wall_s, outputs }
}

fn scale_record(leaders: usize, arm: &ScaleArm) -> Json {
    obj(vec![
        ("label", s("scaling")),
        ("leaders", num(leaders as f64)),
        ("jobs", num(SCALE_JOBS as f64)),
        ("wall_s", num(arm.wall_s)),
        ("jobs_per_s", num(SCALE_JOBS as f64 / arm.wall_s.max(1e-9))),
        ("slo_miss_rate", num(arm.report.slo_miss_rate())),
        ("shed", num(arm.report.shed as f64)),
        ("spilled", num(arm.report.spilled as f64)),
        ("fairness", num(arm.report.fairness)),
    ])
}

fn main() {
    let mut b = Bench::new("federation_scale");
    let mut records = Vec::new();

    // -- scaling: 1 leader vs 2 leaders on the identical job set ----
    // Best of two runs per arm damps scheduler noise; the determinism
    // check uses the first run of each.
    let solo = run_scale_arm(1);
    let duo = run_scale_arm(2);
    assert_eq!(
        solo.outputs, duo.outputs,
        "fleet size must never change a statistic"
    );
    let solo_wall = solo.wall_s.min(run_scale_arm(1).wall_s);
    let duo_wall = duo.wall_s.min(run_scale_arm(2).wall_s);
    let speedup = solo_wall / duo_wall.max(1e-9);
    assert_eq!(
        solo.report.slo_miss_rate(),
        duo.report.slo_miss_rate(),
        "scaling must not move the SLO-miss rate"
    );
    assert_eq!(solo.report.shed, 0, "the scaling set fits the backlog");
    assert_eq!(duo.report.shed, 0);
    records.push(scale_record(1, &solo));
    records.push(scale_record(2, &duo));
    records.push(obj(vec![
        ("label", s("scaling_ratio")),
        ("speedup_1_to_2", num(speedup)),
        ("solo_wall_s", num(solo_wall)),
        ("duo_wall_s", num(duo_wall)),
    ]));
    b.record("speedup_1_to_2", speedup, "x");
    b.record("solo_wall", solo_wall, "s");
    b.record("duo_wall", duo_wall, "s");

    // -- overload: a burst far past the backlog cap ------------------
    let cfg = FederationConfig {
        backlog_cap: BURST_CAP,
        ..scale_cfg(2)
    };
    let mut fed = Federation::start(native(), cfg).expect("start burst");
    let mut accepted = 0u64;
    let mut first_hint = None;
    for j in 0..BURST_JOBS {
        let req = JobRequest::new(Workload::NetflixLo, 8)
            .with_seed(0x0BAD_0000 + j);
        match fed.submit(&format!("burst-{}", j % 8), req) {
            Ok(_) => accepted += 1,
            Err(Error::Shed { retry_after_s, .. }) => {
                assert!(
                    retry_after_s > 0.0,
                    "a shed must carry a positive Retry-After hint"
                );
                if first_hint.is_none() {
                    first_hint = Some(retry_after_s);
                }
            }
            Err(e) => panic!("unexpected refusal: {e}"),
        }
    }
    fed.pump_until_idle(SERVE_JOB_DEADLINE).expect("drain burst");
    let done = fed.drain_completions();
    assert_eq!(done.len() as u64, accepted, "every admitted job finishes");
    assert!(done.iter().all(|c| c.result.is_ok()));
    let report = fed.shutdown().expect("shutdown burst");
    assert!(report.shed > 0, "overload must shed, not queue unboundedly");
    assert_eq!(report.shed + accepted, BURST_JOBS);
    records.push(obj(vec![
        ("label", s("overload")),
        ("submitted", num(BURST_JOBS as f64)),
        ("accepted", num(accepted as f64)),
        ("shed", num(report.shed as f64)),
        ("shed_rate", num(report.shed_rate())),
        ("retry_after_hint_s", num(first_hint.expect("≥1 shed"))),
    ]));
    b.record("overload_shed", report.shed as f64, "jobs");

    // -- tenant_spread: thousands of tenants over ring + DRF ---------
    // The same `Ring::new(leaders, vnodes)` the front-door shards
    // with, at ops-scale vnode density.
    let ring = Ring::new(4, 128);
    let mut counts = [0.0f64; 4];
    for i in 0..RING_TENANTS {
        counts[ring.primary(&format!("tenant-{i:05}"))] += 1.0;
    }
    let placement_fairness = jain_index(&counts);
    assert!(
        placement_fairness > 0.85,
        "ring placement too skewed: {counts:?}"
    );
    let mut rng = Rng::new(0xD2F);
    let demands: Vec<TenantDemand> = (0..DRF_TENANTS)
        .map(|i| TenantDemand {
            tenant: format!("d{i:04}"),
            per_job: Demand { slots: rng.range(1, 4), cache_bytes: 0 },
            jobs: rng.range(1, 8),
        })
        .collect();
    let cap = Capacity { slots: 256, cache_bytes: 0 };
    let t = Instant::now();
    let grants = allocate(cap, &demands);
    let drf_alloc_s = t.elapsed().as_secs_f64();
    let slots_granted: u64 = demands
        .iter()
        .zip(&grants)
        .map(|(d, &g)| d.per_job.slots * g)
        .sum();
    assert!(slots_granted <= cap.slots, "DRF overcommitted the slots");
    let served = grants.iter().filter(|&&g| g > 0).count();
    assert!(
        served >= 64,
        "only {served} of {DRF_TENANTS} tenants progressed on 256 slots"
    );
    records.push(obj(vec![
        ("label", s("tenant_spread")),
        ("ring_tenants", num(RING_TENANTS as f64)),
        ("ring_leaders", num(4.0)),
        ("placement_fairness", num(placement_fairness)),
        ("drf_tenants", num(DRF_TENANTS as f64)),
        ("drf_alloc_s", num(drf_alloc_s)),
        ("drf_slots_granted", num(slots_granted as f64)),
        ("drf_tenants_served", num(served as f64)),
    ]));
    b.record("placement_fairness", placement_fairness, "jain");
    b.record("drf_alloc", drf_alloc_s * 1e3, "ms");

    let path = bts::util::bench_record::write("federation", records)
        .expect("write BENCH_federation.json");
    println!("wrote {path}");
    b.finish();

    // The acceptance bar: a second leader must buy most of its
    // theoretical 2x on a strictly-serialized shard shape.
    assert!(
        speedup >= 1.6,
        "1→2 leader speedup {speedup:.2}x fell below 1.6x \
         (solo {solo_wall:.3}s, duo {duo_wall:.3}s)"
    );
}
