//! Figs 14–16 bench: Netflix scaling on virtualized hardware, job-size
//! sweep, and the reduce-task model.

use bts::data::Workload;
use bts::figures::Ctx;
use bts::platforms::PlatformSpec;
use bts::sim::{
    default_params, simulate, sweep_reduce_tasks, Cluster, HardwareType,
};
use bts::util::bench::Bench;

fn main() {
    let ctx = Ctx::default();
    let mut b = Bench::new("fig14_fig15_fig16_netflix").with_iters(1, 3);
    let hi = ctx.compute_s_per_mib(Workload::NetflixHi);
    let lo = ctx.compute_s_per_mib(Workload::NetflixLo);
    // fig14: virtualized type-3 scaling
    for nodes in [1usize, 2, 4] {
        let cluster = Cluster::homogeneous(HardwareType::TypeIII, nodes);
        let p = default_params(Workload::NetflixHi, 2 << 30, hi);
        let r = simulate(&PlatformSpec::bts(), &cluster, &p);
        b.record(&format!("virt_{}c_tput", nodes * 32), r.throughput_mbs, "MB/s");
    }
    // fig15: job-size sweep, both confidence levels
    let cluster = Cluster::homogeneous(HardwareType::TypeIII, 2);
    for (w, c, tag) in
        [(Workload::NetflixHi, hi, "hi"), (Workload::NetflixLo, lo, "lo")]
    {
        for mb in [256usize, 2048, 16384] {
            let p = default_params(w, mb << 20, c);
            let r = simulate(&PlatformSpec::bts(), &cluster, &p);
            b.record(&format!("{tag}_{mb}MB_tput"), r.throughput_mbs, "MB/s");
        }
    }
    // fig16: reduce sweep
    let cluster = Cluster::homogeneous(HardwareType::TypeII, 6);
    for (w, c, tag) in
        [(Workload::Eaglet, 0.52, "eaglet"), (Workload::NetflixHi, hi, "netflix")]
    {
        let p = default_params(w, 2 << 30, c);
        let sweep = sweep_reduce_tasks(
            &p.reduce,
            2 << 30,
            &cluster,
            &PlatformSpec::bts(),
            &[1, 4, 16, 64],
        );
        for (r, total, _net) in sweep {
            b.record(&format!("{tag}_r{r}_reduce_s"), total, "s");
        }
    }
    b.finish();
}
