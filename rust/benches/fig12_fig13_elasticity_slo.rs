//! Figs 12–13 bench: core-scaling series and the SLO planner.

use bts::data::Workload;
use bts::figures::Ctx;
use bts::platforms::PlatformSpec;
use bts::sim::{default_params, simulate, Cluster, HardwareType};
use bts::util::bench::Bench;

fn main() {
    let ctx = Ctx::default();
    let c = ctx.compute_s_per_mib(Workload::Eaglet);
    let mut b = Bench::new("fig12_fig13_elasticity_slo").with_iters(1, 3);
    for nodes in [1usize, 3, 6] {
        let cluster = Cluster::homogeneous(HardwareType::TypeII, nodes);
        for gb in [2usize, 64] {
            let p = default_params(Workload::Eaglet, gb << 30, c);
            let r = simulate(&PlatformSpec::bts(), &cluster, &p);
            b.record(
                &format!("{}c_{gb}GB_tput", nodes * 12),
                r.throughput_mbs,
                "MB/s",
            );
            if nodes == 6 && gb == 64 {
                b.record("net_util_72c_64GB", r.network_utilization, "frac");
            }
        }
    }
    let jobs: Vec<usize> =
        [64, 230, 1024, 4096, 16384, 65536].iter().map(|m| m << 20).collect();
    for (name, slo) in [("2min", 120.0), ("5min", 300.0), ("10min", 600.0)] {
        if let Some(plan) =
            bts::slo::best_under_slo(Workload::Eaglet, slo, &[12, 36, 72], &jobs, c)
        {
            b.record(&format!("slo_{name}_frac_of_peak"), plan.frac_of_peak, "frac");
        }
    }
    b.measure("slo_planner_wall", || {
        bts::slo::best_under_slo(Workload::Eaglet, 120.0, &[12, 36, 72], &jobs, c);
    });
    b.finish();
}
