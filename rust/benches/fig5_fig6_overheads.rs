//! Figs 5–6 bench: platform overhead models (startup, per-task) plus the
//! REAL measured startup/per-task overheads of this implementation —
//! staging, scheduler construction, monitoring on/off (the §4.2.2
//! experiment re-run for real).

use std::sync::Arc;

use bts::coordinator::{run_job, JobConfig};
use bts::data::eaglet::{EagletConfig, EagletDataset};
use bts::kneepoint::TaskSizing;
use bts::platforms::PlatformSpec;
use bts::runtime::Manifest;
use bts::util::bench::Bench;

fn main() {
    let mut b = Bench::new("fig5_fig6_overheads").with_iters(1, 5);
    // model series (calibrated constants; Figs 5 & 6 shapes)
    for p in [
        PlatformSpec::vanilla_hadoop(),
        PlatformSpec::job_level_hadoop(),
        PlatformSpec::lite_hadoop(),
        PlatformSpec::bts(),
        PlatformSpec::native_linux(),
    ] {
        b.record(&format!("model_startup_{}", p.name), p.startup_s(72), "s");
        b.record(
            &format!("model_pertask_{}", p.name),
            p.per_task_overhead_s(4608.0 / 1048576.0) * 1e3,
            "ms",
        );
    }
    // real platform: startup + per-task overhead, monitoring on/off
    let Ok(m) = Manifest::load("artifacts") else {
        eprintln!("artifacts missing: model series only");
        b.finish();
        return;
    };
    let m = Arc::new(m);
    let ds = EagletDataset::generate(
        &m.params,
        EagletConfig { families: 80, ..Default::default() },
    );
    for monitoring in [false, true] {
        let cfg = JobConfig {
            sizing: TaskSizing::Tiniest,
            workers: 4,
            monitoring,
            ..Default::default()
        };
        let tag = if monitoring { "monitor" } else { "plain" };
        let mut startup = 0.0;
        let mut per_task = 0.0;
        b.measure(&format!("real_job_{tag}"), || {
            let r = run_job(&ds, m.clone(), &cfg).unwrap();
            startup = r.report.startup_s;
            per_task = r.report.map_s / r.report.tasks as f64;
        });
        b.record(&format!("real_startup_{tag}"), startup, "s");
        b.record(&format!("real_pertask_{tag}"), per_task * 1e3, "ms");
    }
    b.finish();
}
