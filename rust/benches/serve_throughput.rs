//! Serve-layer throughput: a warm multi-tenant pool versus cold
//! one-shot clusters on the identical job set.
//!
//!     cargo bench --bench serve_throughput
//!
//! The comparison the serve layer exists to win: N small mixed jobs
//! through the persistent service (one spawn, shared store, tasks
//! interleaved) against the same N jobs each paying `run_cluster`'s
//! spawn/stage/join. Also records the service's sustained tasks/s and
//! end-to-end latency percentiles from its own ServeReport.

use std::sync::Arc;
use std::time::Instant;

use bts::exec::{run_cluster, Backend, ExecConfig};
use bts::runtime::Exec as _;
use bts::serve::{mixed_request, run_load, LoadConfig};
use bts::util::bench::Bench;
use bts::util::testutil::SERVE_JOB_DEADLINE;

fn main() {
    let jobs = 12;
    let load = LoadConfig {
        jobs,
        workers: 4,
        max_active: 4,
        // back-to-back submissions: measure service capacity, not
        // generator pacing
        arrival_rate_per_s: f64::INFINITY,
        base_samples: 24,
        infeasible_every: 0, // feed the pool only admissible work here
        ..Default::default()
    };

    let mut b = Bench::new("serve_throughput").with_iters(1, 3);

    let backend = Arc::new(Backend::native(
        bts::data::ModelParams::default(),
    ));
    let params = backend.manifest().params.clone();

    let be = backend.clone();
    let lc = load.clone();
    b.measure(&format!("serve_warm_pool_{jobs}_jobs"), || {
        // Bounded by the shared serve-layer deadline (the same
        // constant the integration suite waits under): a wedged
        // dispatcher fails the bench loudly instead of hanging CI.
        let t = Instant::now();
        let out = run_load(be.clone(), &lc).expect("serve load");
        assert!(
            t.elapsed() < SERVE_JOB_DEADLINE,
            "serve session exceeded the shared deadline"
        );
        assert_eq!(out.report.jobs_completed, jobs);
        assert_eq!(out.report.worker_respawns(), 0);
    });

    let be = backend.clone();
    let lc = load.clone();
    b.measure(&format!("exec_cold_start_{jobs}_jobs"), || {
        for i in 0..jobs {
            let req = mixed_request(&lc, i);
            let ds = bts::workloads::build_small(
                req.workload,
                &params,
                req.samples,
            );
            let cfg = ExecConfig {
                sizing: req.sizing,
                seed: req.seed,
                ..Default::default()
            };
            run_cluster(ds.as_ref(), be.clone(), &cfg).expect("solo job");
        }
    });

    // One instrumented session for the service's own metrics.
    let out = run_load(backend, &load).expect("serve load");
    b.record("sustained_tasks_per_s", out.report.tasks_per_s(), "tasks/s");
    b.record("e2e_p50", out.report.e2e.p50, "s");
    b.record("e2e_p95", out.report.e2e.p95, "s");
    b.record("queue_wait_p95", out.report.queue_wait.p95, "s");
    b.record(
        "ttfp_p50",
        out.report.ttfp.p50,
        "s",
    );
    b.finish();
}
