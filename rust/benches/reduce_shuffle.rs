//! Executed shuffle + reduce: skew-aware vs hash partitioning under a
//! Zipf-like (Pareto α=1.5) key-weight regime, plus a tiny end-to-end
//! equivalence run of the executed stage.
//!
//!     cargo bench --bench reduce_shuffle
//!
//! Two batteries land in `results/BENCH_reduce.json`:
//!
//! 1. **Partitioner quality** — synthetic key populations drawn from
//!    `Rng::pareto(1.5)` (the hot-key regime the thesis's Netflix
//!    traces exhibit), partitioned by hash and by greedy least-loaded
//!    skew placement. Recorded per configuration: imbalance factor
//!    (max partition load over the balanced ideal) and the modeled
//!    reduce tail (the max-loaded partition is the job's critical
//!    path, so tail ∝ imbalance). Skew is never-worse by
//!    construction; under heavy tails it should beat hash outright.
//! 2. **Executed stage** — one small `run_cluster` job at r=4 (skew)
//!    vs the r=1 map-side-only oracle: bit-identical output, measured
//!    shuffle bytes, measured imbalance hash-vs-skew.

use std::sync::Arc;

use bts::data::{ModelParams, Workload};
use bts::exec::{run_cluster, Backend, ExecConfig};
use bts::kneepoint::TaskSizing;
use bts::reduce::{build_plan, Partitioner};
use bts::util::bench::Bench;
use bts::util::json::{num, obj, s, Json};
use bts::util::rng::Rng;
use bts::workloads::build_small;

const SEED: u64 = 0xB75;
/// Pareto populations per (n_keys, partitions) configuration.
const DRAWS: usize = 25;

fn partitioner_battery(b: &mut Bench, records: &mut Vec<Json>) {
    let configs: &[(usize, usize)] =
        &[(12, 4), (32, 4), (64, 8), (256, 8)];
    let mut rng = Rng::new(SEED);
    for &(n_keys, partitions) in configs {
        let mut hash_sum = 0.0;
        let mut skew_sum = 0.0;
        for _ in 0..DRAWS {
            let weights: Vec<f64> =
                (0..n_keys).map(|_| rng.pareto(1.5)).collect();
            let hash =
                build_plan(Partitioner::Hash, &weights, partitions);
            let skew =
                build_plan(Partitioner::Skew, &weights, partitions);
            let hi = hash.imbalance_factor(&weights);
            let si = skew.imbalance_factor(&weights);
            assert!(
                si <= hi + 1e-12,
                "skew worse than hash on {n_keys} keys x \
                 {partitions}: {si} > {hi}"
            );
            hash_sum += hi;
            skew_sum += si;
        }
        let hash_imb = hash_sum / DRAWS as f64;
        let skew_imb = skew_sum / DRAWS as f64;
        let ratio = hash_imb / skew_imb.max(1e-12);
        assert!(
            ratio >= 1.0,
            "mean skew imbalance must not exceed hash"
        );
        let name = format!("{n_keys}keys_{partitions}parts");
        b.record(&format!("hash_imbalance_{name}"), hash_imb, "x");
        b.record(&format!("skew_imbalance_{name}"), skew_imb, "x");
        b.record(&format!("imbalance_ratio_{name}"), ratio, "x");
        records.push(obj(vec![
            ("label", s("partitioner")),
            ("n_keys", num(n_keys as f64)),
            ("partitions", num(partitions as f64)),
            ("hash_imbalance", num(hash_imb)),
            ("skew_imbalance", num(skew_imb)),
            // The max-loaded partition is the reduce phase's critical
            // path, so the modeled job tail is the imbalance factor
            // itself (1.0 = perfectly balanced tail).
            ("hash_tail", num(hash_imb)),
            ("skew_tail", num(skew_imb)),
            ("tail_ratio", num(ratio)),
        ]));
    }
}

fn executed_battery(b: &mut Bench, records: &mut Vec<Json>) {
    let params = ModelParams::default();
    let backend = Arc::new(Backend::native(params.clone()));
    let ds = build_small(Workload::NetflixLo, &params, 48);
    let cfg = |r: usize, pt: Partitioner| ExecConfig {
        sizing: TaskSizing::Kneepoint(16 * 1024),
        workers: 3,
        seed: SEED,
        reduce_tasks: r,
        partitioner: pt,
        ..Default::default()
    };
    let oracle = run_cluster(
        ds.as_ref(),
        backend.clone(),
        &cfg(1, Partitioner::Hash),
    )
    .expect("r=1 run");
    let hash = run_cluster(
        ds.as_ref(),
        backend.clone(),
        &cfg(4, Partitioner::Hash),
    )
    .expect("r=4 hash run");
    let skew = run_cluster(
        ds.as_ref(),
        backend,
        &cfg(4, Partitioner::Skew),
    )
    .expect("r=4 skew run");
    assert_eq!(
        hash.output, oracle.output,
        "r=4 hash diverged from the map-side oracle"
    );
    assert_eq!(
        skew.output, oracle.output,
        "r=4 skew diverged from the map-side oracle"
    );
    assert!(
        skew.report.shuffle_imbalance
            <= hash.report.shuffle_imbalance + 1e-9,
        "executed skew imbalance must not exceed hash"
    );
    b.record(
        "executed_shuffle_mib",
        skew.report.shuffle_bytes as f64 / 1048576.0,
        "MiB",
    );
    b.record(
        "executed_hash_imbalance",
        hash.report.shuffle_imbalance,
        "x",
    );
    b.record(
        "executed_skew_imbalance",
        skew.report.shuffle_imbalance,
        "x",
    );
    for (mode, r) in [("hash", &hash), ("skew", &skew)] {
        records.push(obj(vec![
            ("label", s("executed")),
            ("partitioner", s(mode)),
            ("reduce_tasks", num(r.report.reduce_tasks as f64)),
            ("shuffle_bytes", num(r.report.shuffle_bytes as f64)),
            ("shuffle_imbalance", num(r.report.shuffle_imbalance)),
            (
                "reduce_turnaround_p99_s",
                num(r.report.reduce_turnaround.p99),
            ),
            ("total_s", num(r.report.total_s)),
        ]));
    }
}

fn main() {
    let mut b = Bench::new("reduce_shuffle");
    let mut records = Vec::new();
    partitioner_battery(&mut b, &mut records);
    executed_battery(&mut b, &mut records);
    let path = bts::util::bench_record::write("reduce", records)
        .expect("write BENCH_reduce.json");
    println!("wrote {path}");
    b.finish();
}
