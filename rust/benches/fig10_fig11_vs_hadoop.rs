//! Figs 10–11 bench: BTS vs Hadoop setups across job sizes (simulated
//! testbed; constants calibrated per DESIGN.md §6). Records the series
//! the paper plots and times the simulator itself.

use bts::figures::Ctx;
use bts::platforms::PlatformSpec;
use bts::sim::{default_params, simulate, Cluster, HardwareType};
use bts::data::Workload;
use bts::util::bench::Bench;

fn main() {
    let ctx = Ctx::default();
    let mut b = Bench::new("fig10_fig11_vs_hadoop").with_iters(1, 3);
    let cluster = Cluster::homogeneous(HardwareType::TypeII, 6);
    let c = ctx.compute_s_per_mib(Workload::Eaglet);
    for mb in [12usize, 91, 230, 1024, 4096, 16384] {
        let p = default_params(Workload::Eaglet, mb * 1024 * 1024, c);
        let bts = simulate(&PlatformSpec::bts(), &cluster, &p);
        let vh = simulate(&PlatformSpec::vanilla_hadoop(), &cluster, &p);
        let jlh = simulate(&PlatformSpec::job_level_hadoop(), &cluster, &p);
        let lh = simulate(&PlatformSpec::lite_hadoop(), &cluster, &p);
        b.record(&format!("{mb}MB_bts_total"), bts.total_s, "s");
        b.record(&format!("{mb}MB_vh_over_bts"), vh.total_s / bts.total_s, "x");
        b.record(&format!("{mb}MB_jlh_over_bts"), jlh.total_s / bts.total_s, "x");
        b.record(&format!("{mb}MB_lh_over_bts"), lh.total_s / bts.total_s, "x");
    }
    // simulator wallclock (it must stay cheap enough for planners)
    let p = default_params(Workload::Eaglet, 16 << 30, c);
    b.measure("simulate_16GB_job_wall", || {
        simulate(&PlatformSpec::bts(), &cluster, &p);
    });
    b.finish();
}
