//! Transport overhead: the distribution half of the thesis's tiny-task
//! trade, now a measured, swappable axis.
//!
//!     cargo bench --bench transport_overhead
//!     cargo bench --bench transport_overhead --features alloc-count
//!
//! Runs the same job (same seed, same packing) over the transports and
//! prices what changed:
//!
//! * **per-task dispatch** — leader-side scheduler claim + link send
//!   (`SchedOverhead.dispatch_s / tasks`), mpsc channel vs framed
//!   loopback TCP, with dispatch batching on vs off. Batched, every
//!   refill window leaves as one `TaskBatch` frame with one flush;
//!   unbatched reproduces the historical frame-and-flush-per-task
//!   path. **Gate:** at 1k+ tiny tasks over loopback TCP, batching
//!   must cut per-task dispatch overhead by at least 2x.
//! * **data distribution** — per-task fetch time with blocks served
//!   from the local replicated store (in-proc) vs leader-proxied
//!   `DfsGet` over the socket, with and without a worker-local block
//!   cache in front of the wire.
//! * **allocation discipline** (`--features alloc-count`) — a warm
//!   cache-hit block fetch must perform **zero** heap allocations:
//!   intrusive-LRU touch plus an `Arc` clone, nothing else.
//!
//! Outputs are asserted bit-identical across all configurations
//! before anything is recorded (a perf number for a wrong answer is
//! noise). Writes the trajectory record to
//! `results/BENCH_transport.json`.

use std::sync::Arc;
use std::thread;

use bts::data::{ModelParams, Workload};
use bts::exec::{run_cluster, Backend, ExecConfig, ExecResult};
use bts::kneepoint::TaskSizing;
use bts::net::run_worker;
use bts::transport::{RemoteWorkerOpts, RemoteWorkers};
use bts::util::bench::Bench;
use bts::util::json::{num, obj, s, Json};

#[cfg(feature = "alloc-count")]
#[global_allocator]
static ALLOC: bts::util::alloc_counter::CountingAlloc =
    bts::util::alloc_counter::CountingAlloc;

const SEED: u64 = 0xB75;
/// Tiniest sizing → one task per sample: the 1k+ tiny-task regime the
/// dispatch-overhead gate is defined over.
const SAMPLES: usize = 1024;

fn base_cfg() -> ExecConfig {
    ExecConfig {
        sizing: TaskSizing::Tiniest,
        seed: SEED,
        // A deeper dispatch window means wider refill bursts — the
        // batch window IS the refill window, so this is the one knob
        // that shapes TaskBatch sizes.
        inflight: 8,
        ..Default::default()
    }
}

/// Leader wall time in the dispatch path (claim + link send + report)
/// amortized per task — the overhead the tiny-task trade pays.
fn dispatch_us_per_task(r: &ExecResult) -> f64 {
    r.overhead.dispatch_s * 1e6 / r.report.tasks.max(1) as f64
}

/// One TCP run: bind, stand up `n` remote worker sessions, run the
/// job over `local` in-proc slots + the remotes.
fn run_tcp(
    backend: &Arc<Backend>,
    ds: &dyn bts::data::Dataset,
    local: usize,
    n_remote: usize,
    worker_cache_mb: usize,
    batch: bool,
) -> ExecResult {
    let remote = RemoteWorkers::bind("127.0.0.1:0", n_remote)
        .expect("bind loopback");
    let addr = remote.addr();
    let workers: Vec<_> = (0..n_remote)
        .map(|_| {
            let addr = addr.clone();
            let backend = backend.clone();
            thread::spawn(move || {
                run_worker(
                    &addr,
                    backend,
                    &RemoteWorkerOpts {
                        cache_mb: worker_cache_mb,
                        ..Default::default()
                    },
                )
                .expect("worker session")
            })
        })
        .collect();
    let r = run_cluster(
        ds,
        backend.clone(),
        &ExecConfig {
            workers: local,
            remote: Some(remote),
            batch_dispatch: batch,
            ..base_cfg()
        },
    )
    .expect("tcp run");
    for h in workers {
        h.join().unwrap();
    }
    r
}

fn flat(name: &str, r: &ExecResult) -> Json {
    obj(vec![
        ("label", s(name)),
        ("tasks", num(r.report.tasks as f64)),
        ("dispatch_us_per_task", num(dispatch_us_per_task(r))),
        (
            "dispatch_us_per_call",
            num(r.overhead.dispatch_us_per_call()),
        ),
        ("queue_wait_p50_s", num(r.overhead.queue_wait.p50)),
        ("queue_wait_p95_s", num(r.overhead.queue_wait.p95)),
        ("task_fetch_p50_s", num(r.report.task_fetch.p50)),
        ("task_fetch_p95_s", num(r.report.task_fetch.p95)),
        ("task_exec_p50_s", num(r.report.task_exec.p50)),
        ("map_s", num(r.report.map_s)),
        ("total_s", num(r.report.total_s)),
        ("dfs_bytes_served", num(r.dfs_bytes_served as f64)),
        ("prefetch_hit_rate", num(r.report.prefetch_hit_rate)),
        ("cache_hit_rate", num(r.report.cache_hit_rate)),
        ("frames_sent", num(r.report.frames_sent as f64)),
        ("frames_batched", num(r.report.frames_batched as f64)),
        ("wire_bytes", num(r.report.wire_bytes as f64)),
        ("blocks_zero_copy", num(r.report.blocks_zero_copy as f64)),
    ])
}

/// Warm cache-hit allocation audit: a hit on protected content is an
/// index lookup, an intrusive-list touch, and an `Arc` clone — zero
/// heap traffic. Only meaningful when this binary owns the global
/// allocator, hence the feature gate.
#[cfg(feature = "alloc-count")]
fn assert_warm_hit_allocates_nothing() {
    use bts::cache::BlockCache;
    use bts::util::alloc_counter;

    let cache = BlockCache::new(1 << 20, 2);
    let data = Arc::new(vec![7u8; 4096]);
    cache.insert("bench/warm", &data);
    // First hit promotes probation → protected (still alloc-free, but
    // the contract under test is the steady warm state).
    let first = cache.get("bench/warm").expect("resident");
    drop(first);

    alloc_counter::reset();
    let hit = cache.get("bench/warm").expect("warm hit");
    let n = alloc_counter::allocations();
    assert_eq!(
        n, 0,
        "warm cache-hit fetch allocated {n} times; the zero-copy \
         contract says an intrusive-LRU touch + Arc clone only"
    );
    drop(hit);
    println!("alloc-count: warm cache hit performed 0 heap allocations");
}

fn main() {
    let backend = Arc::new(Backend::native(ModelParams::default()));
    let mut b = Bench::new("transport_overhead").with_iters(0, 1);
    let ds = bts::workloads::build_small(
        Workload::Eaglet,
        &ModelParams::default(),
        SAMPLES,
    );

    // ---- in-proc channels: the baseline spine -----------------------
    let inproc = run_cluster(
        ds.as_ref(),
        backend.clone(),
        &ExecConfig { workers: 2, ..base_cfg() },
    )
    .expect("inproc run");

    // ---- loopback TCP: same slot count, framed transport ------------
    let tcp = run_tcp(&backend, ds.as_ref(), 0, 2, 0, true);
    // ---- same wire, batching off: one frame + flush per task --------
    let tcp_unbatched = run_tcp(&backend, ds.as_ref(), 0, 2, 0, false);
    // ---- loopback TCP + worker-local cache over the data plane ------
    let tcp_cached = run_tcp(&backend, ds.as_ref(), 0, 2, 32, true);
    // ---- mixed: one local slot, one remote --------------------------
    let mixed = run_tcp(&backend, ds.as_ref(), 1, 1, 0, true);

    // A perf number for a wrong answer is noise: equivalence first.
    assert_eq!(inproc.output, tcp.output, "tcp changed the statistic");
    assert_eq!(
        inproc.output, tcp_unbatched.output,
        "batching changed the statistic"
    );
    assert_eq!(
        inproc.output, tcp_cached.output,
        "worker cache changed the statistic"
    );
    assert_eq!(inproc.output, mixed.output, "mixed set changed the statistic");
    assert!(
        inproc.report.tasks >= 1024,
        "gate regime needs 1k+ tiny tasks, got {}",
        inproc.report.tasks
    );

    for (name, r) in [
        ("inproc", &inproc),
        ("tcp", &tcp),
        ("tcp_unbatched", &tcp_unbatched),
        ("tcp_worker_cache", &tcp_cached),
        ("mixed", &mixed),
    ] {
        b.record(
            &format!("{name}_dispatch_us_per_task"),
            dispatch_us_per_task(r),
            "us",
        );
        b.record(
            &format!("{name}_task_fetch_p50"),
            r.report.task_fetch.p50,
            "s",
        );
        b.record(&format!("{name}_map"), r.report.map_s, "s");
        println!(
            "{name:>16}: dispatch {:6.2} us/task  fetch p50 {:8.6}s  \
             queue-wait p50 {:8.6}s  map {:.3}s  ({} tasks, {} frames, \
             {} batched, {:.2} MB wire)",
            dispatch_us_per_task(r),
            r.report.task_fetch.p50,
            r.overhead.queue_wait.p50,
            r.report.map_s,
            r.report.tasks,
            r.report.frames_sent,
            r.report.frames_batched,
            r.report.wire_bytes as f64 / 1048576.0,
        );
    }

    // ---- the gate: batching must at least halve per-task dispatch ---
    let batched_us = dispatch_us_per_task(&tcp);
    let unbatched_us = dispatch_us_per_task(&tcp_unbatched);
    println!(
        "gate: unbatched {unbatched_us:.2} us/task vs batched \
         {batched_us:.2} us/task ({:.2}x)",
        unbatched_us / batched_us.max(1e-9)
    );
    assert!(
        unbatched_us >= 2.0 * batched_us,
        "batched dispatch must be >= 2x cheaper per task over loopback \
         TCP: unbatched {unbatched_us:.2} us/task, batched \
         {batched_us:.2} us/task"
    );
    assert!(
        tcp.report.frames_batched > 0,
        "batched run sent no TaskBatch/DoneBatch members"
    );

    #[cfg(feature = "alloc-count")]
    assert_warm_hit_allocates_nothing();

    let records = vec![
        flat("inproc", &inproc),
        flat("tcp", &tcp),
        flat("tcp_unbatched", &tcp_unbatched),
        flat("tcp_worker_cache", &tcp_cached),
        flat("mixed_local_remote", &mixed),
    ];
    let path = bts::util::bench_record::write("transport", records)
        .expect("write BENCH_transport.json");
    println!("wrote {path}");

    b.finish();
}
