//! Transport overhead: the distribution half of the thesis's tiny-task
//! trade, now a measured, swappable axis.
//!
//!     cargo bench --bench transport_overhead
//!
//! Runs the same job (same seed, same packing) over the two
//! transports and prices what changed:
//!
//! * **per-task dispatch** — leader-side scheduler claim + link send
//!   (`SchedOverhead::dispatch_us_per_call`), mpsc channel vs framed
//!   loopback TCP;
//! * **data distribution** — per-task fetch time with blocks served
//!   from the local replicated store (in-proc) vs leader-proxied
//!   `DfsGet` over the socket, with and without a worker-local block
//!   cache in front of the wire.
//!
//! Outputs are asserted bit-identical across all configurations
//! before anything is recorded (a perf number for a wrong answer is
//! noise). Writes the trajectory record to
//! `results/BENCH_transport.json`.

use std::sync::Arc;
use std::thread;

use bts::data::{ModelParams, Workload};
use bts::exec::{run_cluster, Backend, ExecConfig, ExecResult};
use bts::kneepoint::TaskSizing;
use bts::net::run_worker;
use bts::transport::{RemoteWorkerOpts, RemoteWorkers};
use bts::util::bench::Bench;
use bts::util::json::{num, obj, s, Json};

const SEED: u64 = 0xB75;
const SAMPLES: usize = 96;

fn base_cfg() -> ExecConfig {
    ExecConfig {
        sizing: TaskSizing::Kneepoint(16 * 1024),
        seed: SEED,
        ..Default::default()
    }
}

/// One TCP run: bind, stand up `n` remote worker sessions, run the
/// job over `local` in-proc slots + the remotes.
fn run_tcp(
    backend: &Arc<Backend>,
    ds: &dyn bts::data::Dataset,
    local: usize,
    n_remote: usize,
    worker_cache_mb: usize,
) -> ExecResult {
    let remote = RemoteWorkers::bind("127.0.0.1:0", n_remote)
        .expect("bind loopback");
    let addr = remote.addr();
    let workers: Vec<_> = (0..n_remote)
        .map(|_| {
            let addr = addr.clone();
            let backend = backend.clone();
            thread::spawn(move || {
                run_worker(
                    &addr,
                    backend,
                    &RemoteWorkerOpts {
                        cache_mb: worker_cache_mb,
                        ..Default::default()
                    },
                )
                .expect("worker session")
            })
        })
        .collect();
    let r = run_cluster(
        ds,
        backend.clone(),
        &ExecConfig {
            workers: local,
            remote: Some(remote),
            ..base_cfg()
        },
    )
    .expect("tcp run");
    for h in workers {
        h.join().unwrap();
    }
    r
}

fn flat(name: &str, r: &ExecResult) -> Json {
    obj(vec![
        ("config", s(name)),
        ("tasks", num(r.report.tasks as f64)),
        (
            "dispatch_us_per_task",
            num(r.overhead.dispatch_us_per_call()),
        ),
        ("queue_wait_p50_s", num(r.overhead.queue_wait.p50)),
        ("queue_wait_p95_s", num(r.overhead.queue_wait.p95)),
        ("task_fetch_p50_s", num(r.report.task_fetch.p50)),
        ("task_fetch_p95_s", num(r.report.task_fetch.p95)),
        ("task_exec_p50_s", num(r.report.task_exec.p50)),
        ("map_s", num(r.report.map_s)),
        ("total_s", num(r.report.total_s)),
        ("dfs_bytes_served", num(r.dfs_bytes_served as f64)),
        ("prefetch_hit_rate", num(r.report.prefetch_hit_rate)),
        ("cache_hit_rate", num(r.report.cache_hit_rate)),
    ])
}

fn main() {
    let backend = Arc::new(Backend::native(ModelParams::default()));
    let mut b = Bench::new("transport_overhead").with_iters(0, 1);
    let ds =
        bts::workloads::build_small(Workload::Eaglet, &ModelParams::default(), SAMPLES);

    // ---- in-proc channels: the baseline spine -----------------------
    let inproc = run_cluster(
        ds.as_ref(),
        backend.clone(),
        &ExecConfig { workers: 2, ..base_cfg() },
    )
    .expect("inproc run");

    // ---- loopback TCP: same slot count, framed transport ------------
    let tcp = run_tcp(&backend, ds.as_ref(), 0, 2, 0);
    // ---- loopback TCP + worker-local cache over the data plane ------
    let tcp_cached = run_tcp(&backend, ds.as_ref(), 0, 2, 32);
    // ---- mixed: one local slot, one remote --------------------------
    let mixed = run_tcp(&backend, ds.as_ref(), 1, 1, 0);

    // A perf number for a wrong answer is noise: equivalence first.
    assert_eq!(inproc.output, tcp.output, "tcp changed the statistic");
    assert_eq!(
        inproc.output, tcp_cached.output,
        "worker cache changed the statistic"
    );
    assert_eq!(inproc.output, mixed.output, "mixed set changed the statistic");

    for (name, r) in [
        ("inproc", &inproc),
        ("tcp", &tcp),
        ("tcp_worker_cache", &tcp_cached),
        ("mixed", &mixed),
    ] {
        b.record(
            &format!("{name}_dispatch_us_per_task"),
            r.overhead.dispatch_us_per_call(),
            "us",
        );
        b.record(
            &format!("{name}_task_fetch_p50"),
            r.report.task_fetch.p50,
            "s",
        );
        b.record(&format!("{name}_map"), r.report.map_s, "s");
        println!(
            "{name:>18}: dispatch {:6.1} us/task  fetch p50 {:8.6}s  \
             queue-wait p50 {:8.6}s  map {:.3}s  ({} tasks, {:.2} MB served)",
            r.overhead.dispatch_us_per_call(),
            r.report.task_fetch.p50,
            r.overhead.queue_wait.p50,
            r.report.map_s,
            r.report.tasks,
            r.dfs_bytes_served as f64 / 1048576.0,
        );
    }

    let records = vec![
        flat("inproc", &inproc),
        flat("tcp", &tcp),
        flat("tcp_worker_cache", &tcp_cached),
        flat("mixed_local_remote", &mixed),
    ];
    let path = bts::util::bench_record::write("transport", records)
        .expect("write BENCH_transport.json");
    println!("wrote {path}");

    b.finish();
}
