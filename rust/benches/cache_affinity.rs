//! Cache + affinity: a warm-cache second tenant versus its cold first
//! run over the shared serve pool, and affinity-routed refills versus
//! plain FIFO on the solo executor.
//!
//!     cargo bench --bench cache_affinity
//!
//! The modeled data-node latency actually sleeps here, so the cold
//! run pays real wall time per fetch and the warm tenant's hit rate
//! is visible as a speedup, not just a counter. Writes the trajectory
//! record to `results/BENCH_cache.json`.

use std::sync::Arc;

use bts::data::{ModelParams, Workload};
use bts::dfs::LatencyModel;
use bts::exec::{run_cluster, Backend, ExecConfig};
use bts::kneepoint::TaskSizing;
use bts::serve::{JobRequest, JobService, PoolConfig, ServeConfig};
use bts::util::bench::Bench;
use bts::util::json::{num, obj, s};

fn main() {
    let backend = Arc::new(Backend::native(ModelParams::default()));
    let mut b = Bench::new("cache_affinity").with_iters(0, 1);

    // ---- serve: cold tenant, then an identical warm tenant ----------
    // every store fetch sleeps ~1.5ms, so misses cost real time
    let latency = LatencyModel {
        base_s: 1.5e-3,
        per_mib_s: 2e-3,
        per_inflight_s: 0.0,
        sleep: true,
    };
    let svc = JobService::start(
        backend.clone(),
        ServeConfig {
            pool: PoolConfig {
                workers: 4,
                cache_mb: 64,
                affinity: true,
                latency: latency.clone(),
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .expect("service");
    let req = JobRequest::new(Workload::Eaglet, 48)
        .with_seed(0xCAFE)
        .with_sizing(TaskSizing::Kneepoint(16 * 1024));
    let cold = svc.submit(req.clone()).expect("admit").wait().expect("cold");
    let warm = svc.submit(req.clone()).expect("admit").wait().expect("warm");
    assert_eq!(cold.output, warm.output, "cache changed the statistic");
    assert!(
        warm.report.cache_hit_rate > 0.9,
        "warm tenant hit only {:.2}",
        warm.report.cache_hit_rate
    );
    assert!(
        warm.e2e_s < cold.e2e_s,
        "warm job ({:.1}ms) not faster than cold ({:.1}ms)",
        warm.e2e_s * 1e3,
        cold.e2e_s * 1e3
    );
    let report = svc.shutdown().expect("report");
    let stats = report.cache.clone().expect("cache stats");
    b.record("serve_cold_e2e", cold.e2e_s, "s");
    b.record("serve_warm_e2e", warm.e2e_s, "s");
    b.record("serve_warm_speedup", cold.e2e_s / warm.e2e_s.max(1e-9), "x");
    b.record("serve_warm_hit_rate", warm.report.cache_hit_rate, "frac");
    b.record("serve_dedup_hits", stats.dedup_hits as f64, "blocks");
    println!(
        "cold {:.1}ms -> warm {:.1}ms ({:.1}x); warm hit rate {:.0}%; \
         {} dedup aliases",
        cold.e2e_s * 1e3,
        warm.e2e_s * 1e3,
        cold.e2e_s / warm.e2e_s.max(1e-9),
        warm.report.cache_hit_rate * 100.0,
        stats.dedup_hits
    );

    // ---- exec: affinity-routed refills vs plain FIFO ----------------
    let ds = bts::workloads::build_small(
        Workload::NetflixHi,
        &ModelParams::default(),
        96,
    );
    let base = ExecConfig {
        sizing: TaskSizing::Kneepoint(16 * 1024),
        workers: 4,
        cache_mb: 64,
        latency: latency.clone(),
        ..Default::default()
    };
    let plain_cfg = ExecConfig { affinity: false, ..base.clone() };
    let affine_cfg = ExecConfig { affinity: true, ..base.clone() };
    let be = backend.clone();
    let dsr = ds.as_ref();
    let mut plain_s = f64::INFINITY;
    let mut affine_s = f64::INFINITY;
    let mut routed = 0u64;
    b.measure("exec_fifo_refills", || {
        let r = run_cluster(dsr, be.clone(), &plain_cfg).expect("run");
        plain_s = plain_s.min(r.report.total_s);
    });
    let be = backend.clone();
    b.measure("exec_affinity_refills", || {
        let r = run_cluster(dsr, be.clone(), &affine_cfg).expect("run");
        affine_s = affine_s.min(r.report.total_s);
        routed = routed.max(r.sched.affinity_routed);
    });
    b.record("exec_affinity_routed", routed as f64, "tasks");

    // ---- trajectory record ------------------------------------------
    let record = obj(vec![
        ("label", s("cache_affinity")),
        ("serve_cold_e2e_s", num(cold.e2e_s)),
        ("serve_warm_e2e_s", num(warm.e2e_s)),
        (
            "serve_warm_speedup",
            num(cold.e2e_s / warm.e2e_s.max(1e-9)),
        ),
        ("serve_warm_hit_rate", num(warm.report.cache_hit_rate)),
        ("serve_cache_dedup_hits", num(stats.dedup_hits as f64)),
        ("serve_cache_evictions", num(stats.evicted as f64)),
        ("exec_fifo_total_s", num(plain_s)),
        ("exec_affinity_total_s", num(affine_s)),
        ("exec_affinity_routed", num(routed as f64)),
    ]);
    let path = bts::util::bench_record::write("cache", vec![record])
        .expect("write BENCH_cache.json");
    println!("wrote {path}");

    b.finish();
}
