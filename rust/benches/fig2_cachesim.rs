//! Fig 2 bench: the task-size → miss-rate/AMAT curve on the simulated
//! Sandy Bridge, plus the wallclock cost of profiling itself (the
//! "offline phase ≈ 3% of online" claim depends on it being cheap).

use bts::cachesim::{CacheConfig, Hierarchy, TraceConfig, run_task_trace};
use bts::util::bench::Bench;

fn main() {
    let mut b = Bench::new("fig2_cachesim").with_iters(1, 5);
    let cache = CacheConfig::sandy_bridge();
    for mb in [1usize, 2, 4, 8, 11, 16, 25] {
        let bytes = mb * 1024 * 1024;
        let mut h = Hierarchy::new(cache.clone());
        run_task_trace(&TraceConfig::eaglet(bytes), &mut h);
        b.record(&format!("eaglet_{mb}MB_l2_mpi"), h.l2_mpi(), "miss/instr");
        b.record(&format!("eaglet_{mb}MB_amat"), h.amat(), "cycles");
        b.measure(&format!("profile_{mb}MB_wall"), || {
            let mut h = Hierarchy::new(cache.clone());
            run_task_trace(&TraceConfig::eaglet(bytes), &mut h);
        });
    }
    b.finish();
}
