//! Exec-pipeline bench: whole jobs through the channel-based cluster
//! executor on the native kernel backend (runs on every host — no
//! artifacts). Records the trade the thesis quantifies: per-task
//! latency, leader dispatch overhead, and throughput across sizing
//! policies and worker counts. These numbers are the baseline for
//! BENCH_*.json trajectory entries (see results/exec_pipeline.csv and
//! results/exec_baseline.json from examples/end_to_end.rs).

use std::sync::Arc;

use bts::data::{ModelParams, Workload};
use bts::exec::{run_cluster, Backend, ExecConfig};
use bts::kneepoint::TaskSizing;
use bts::util::bench::Bench;
use bts::workloads::build_small;

fn main() {
    let params = ModelParams::default();
    let backend = Arc::new(Backend::native(params.clone()));
    let mut b = Bench::new("exec_pipeline").with_iters(1, 5);
    for (w, n_samples) in
        [(Workload::Eaglet, 200usize), (Workload::NetflixLo, 800)]
    {
        let ds = build_small(w, &params, n_samples);
        for (sizing, name) in [
            (TaskSizing::Tiniest, "tiniest"),
            (TaskSizing::Kneepoint(256 * 1024), "knee256k"),
        ] {
            for workers in [1usize, 4] {
                let cfg = ExecConfig { sizing, workers, ..Default::default() };
                let tag = format!("{}_{name}_{workers}w", w.name());
                let mut last = None;
                b.measure(&tag, || {
                    last = Some(
                        run_cluster(ds.as_ref(), backend.clone(), &cfg)
                            .unwrap(),
                    );
                });
                if let Some(r) = last {
                    b.record(
                        &format!("{tag}_exec_p50_ms"),
                        r.report.task_exec.p50 * 1e3,
                        "ms",
                    );
                    b.record(
                        &format!("{tag}_dispatch_us_per_call"),
                        r.overhead.dispatch_us_per_call(),
                        "us",
                    );
                    b.record(
                        &format!("{tag}_queue_wait_p50_ms"),
                        r.overhead.queue_wait.p50 * 1e3,
                        "ms",
                    );
                    b.record(
                        &format!("{tag}_tput"),
                        r.report.throughput_mbs(),
                        "MB/s",
                    );
                }
            }
        }
    }
    b.finish();
}
