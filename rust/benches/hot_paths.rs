//! Hot-path micro benches: everything on or near the per-task critical
//! path. §Perf in EXPERIMENTS.md tracks these before/after.

use std::sync::Arc;

use bts::coordinator::assemble::MapTask;
use bts::data::eaglet::{EagletConfig, EagletDataset};
use bts::data::netflix::{NetflixConfig, NetflixDataset};
use bts::data::{Dataset, SampleMeta, Workload};
use bts::dfs::{Dfs, LatencyModel, Prefetcher};
use bts::kneepoint::{pack, TaskSizing};
use bts::runtime::Manifest;
use bts::scheduler::{SchedConfig, TaskSpec, TwoStepScheduler};
use bts::util::bench::Bench;

fn main() {
    let mut b = Bench::new("hot_paths").with_iters(3, 20);

    // --- scheduler: claim+report round trip -----------------------------
    let metas: Vec<SampleMeta> = (0..20_000u64)
        .map(|id| SampleMeta { id, bytes: 4608, units: 1 })
        .collect();
    b.measure("sched_20k_tasks_4_workers", || {
        let specs: Vec<TaskSpec> = pack(&metas, TaskSizing::Tiniest)
            .into_iter()
            .map(|t| TaskSpec::new(t, Workload::Eaglet, 1))
            .collect();
        let s = TwoStepScheduler::new(specs, 4, SchedConfig::default());
        let mut more = true;
        while more {
            more = false;
            for w in 0..4 {
                if let Some(_t) = s.next(w) {
                    s.report(w, 0.0, 0.001);
                    more = true;
                }
            }
        }
    });

    // --- packing ----------------------------------------------------------
    b.measure("pack_100k_samples_kneepoint", || {
        let metas: Vec<SampleMeta> = (0..100_000u64)
            .map(|id| SampleMeta { id, bytes: 4608, units: 2 })
            .collect();
        std::hint::black_box(pack(&metas, TaskSizing::Kneepoint(256 * 1024)));
    });

    // --- dfs + prefetcher -------------------------------------------------
    let dfs = Dfs::new(4, 2, LatencyModel::none());
    for k in 0..512 {
        dfs.put(&format!("k{k}"), Arc::new(vec![7u8; 4608]));
    }
    b.measure("dfs_get_512_blocks", || {
        for k in 0..512 {
            std::hint::black_box(dfs.get(&format!("k{k}")).unwrap());
        }
    });
    b.measure("prefetch_pump_take_256", || {
        let mut pf = Prefetcher::new(dfs.clone(), 8);
        pf.enqueue((0..256).map(|k| format!("k{k}")));
        for k in 0..256 {
            pf.pump().unwrap();
            std::hint::black_box(pf.take(&format!("k{k}")).unwrap());
            pf.observe_exec(0.0005);
        }
    });

    // --- block encode/decode + assemble ------------------------------------
    let params = bts::data::ModelParams::default();
    let eaglet = EagletDataset::generate(
        &params,
        EagletConfig { families: 64, ..Default::default() },
    );
    let blocks: Vec<_> = (2..18).map(|id| eaglet.encode_block(id)).collect();
    b.measure("block_encode_decode_16", || {
        for blk in &blocks {
            let enc = blk.encode();
            std::hint::black_box(
                bts::data::Block::decode(&enc).unwrap(),
            );
        }
    });
    b.measure("assemble_eaglet_16_families", || {
        std::hint::black_box(
            MapTask::slices(&params, Workload::Eaglet, &blocks, 7).unwrap(),
        );
    });
    let netflix = NetflixDataset::generate(
        &params,
        NetflixConfig { movies: 64, ..Default::default() },
    );
    let nblocks: Vec<_> = (0..64).map(|id| netflix.encode_block(id)).collect();
    b.measure("assemble_netflix_64_movies", || {
        std::hint::black_box(
            MapTask::slices(&params, Workload::NetflixLo, &nblocks, 7)
                .unwrap(),
        );
    });

    // --- PJRT execution per bucket -----------------------------------------
    if let Ok(m) = Manifest::load("artifacts") {
        let m = Arc::new(m);
        let rt = bts::runtime::Runtime::new(m.clone()).unwrap();
        for bucket in [1usize, 4, 16, 64] {
            let e = m.entry("eaglet_map", bucket).unwrap().clone();
            let inputs: Vec<bts::runtime::HostTensor> = e
                .inputs
                .iter()
                .map(|spec| match spec.dtype {
                    bts::runtime::Dtype::F32 => bts::runtime::HostTensor::F32(
                        vec![0.5; spec.elements()],
                        spec.shape.clone(),
                    ),
                    bts::runtime::Dtype::I32 => bts::runtime::HostTensor::I32(
                        vec![1; spec.elements()],
                        spec.shape.clone(),
                    ),
                })
                .collect();
            rt.execute(&e, &inputs).unwrap(); // compile outside timing
            b.measure(&format!("pjrt_eaglet_map_b{bucket}"), || {
                std::hint::black_box(rt.execute(&e, &inputs).unwrap());
            });
        }
    } else {
        eprintln!("artifacts missing: skipping PJRT benches");
    }
    b.finish();
}
