//! Ablation benches (DESIGN.md §8): each of the platform's design
//! choices switched off in isolation, on the REAL engine, to show what
//! it buys. Complements the paper-figure benches, which compare whole
//! platforms.
//!
//!   * two-step scheduler vs one-task-at-a-time dispatch (lead_s=0,
//!     batch=1, no stealing) — the thesis's "a few milliseconds wait
//!     time on a millisecond job would be significantly higher"
//!   * prefetching on vs off (k=1) under LAN latency
//!   * adaptive replication vs fixed rf=1 under LAN latency
//!   * work stealing on vs off with an imbalance-inducing task mix

use std::sync::Arc;

use bts::coordinator::{run_job, JobConfig};
use bts::data::Workload;
use bts::dfs::LatencyModel;
use bts::kneepoint::TaskSizing;
use bts::runtime::Manifest;
use bts::scheduler::SchedConfig;
use bts::util::bench::Bench;
use bts::workloads::build_small;

fn main() {
    let Ok(m) = Manifest::load("artifacts") else {
        eprintln!("skipping ablations: run `make artifacts`");
        return;
    };
    let m = Arc::new(m);
    let mut b = Bench::new("ablations").with_iters(1, 5);

    let ds = build_small(Workload::Eaglet, &m.params, 200);
    let nf = build_small(Workload::NetflixLo, &m.params, 1000);

    // --- scheduler: two-step vs single-dispatch ------------------------
    let two_step = JobConfig {
        sizing: TaskSizing::Tiniest,
        workers: 4,
        ..Default::default()
    };
    let single = JobConfig {
        sched: SchedConfig {
            lead_s: 0.0,
            max_batch: 1,
            max_queue: 2,
            steal: false,
            ..Default::default()
        },
        ..two_step.clone()
    };
    let mut t = 0.0;
    b.measure("sched_two_step", || {
        t = run_job(ds.as_ref(), m.clone(), &two_step).unwrap().report.total_s;
    });
    b.record("sched_two_step_total", t, "s");
    b.measure("sched_single_dispatch", || {
        t = run_job(ds.as_ref(), m.clone(), &single).unwrap().report.total_s;
    });
    b.record("sched_single_dispatch_total", t, "s");

    // --- prefetch: k=8 vs k=1 under LAN latency ------------------------
    for (k, name) in [(8usize, "prefetch_k8"), (1, "prefetch_off")] {
        let cfg = JobConfig {
            sizing: TaskSizing::Tiniest,
            workers: 2,
            latency: LatencyModel::lan(),
            prefetch_k: k,
            ..Default::default()
        };
        let mut hit = 0.0;
        b.measure(name, || {
            let r = run_job(nf.as_ref(), m.clone(), &cfg).unwrap();
            t = r.report.total_s;
            hit = r.report.prefetch_hit_rate;
        });
        b.record(&format!("{name}_total"), t, "s");
        b.record(&format!("{name}_hit_rate"), hit, "frac");
    }

    // --- replication: adaptive vs pinned rf=1 under LAN ----------------
    for (adaptive, name) in [(true, "rf_adaptive"), (false, "rf_fixed1")] {
        let mut cfg = JobConfig {
            sizing: TaskSizing::Tiniest,
            workers: 4,
            data_nodes: 8,
            latency: LatencyModel::lan(),
            adaptive_rf: adaptive,
            ..Default::default()
        };
        if !adaptive {
            cfg.replication.min_rf = 1;
            cfg.replication.max_rf = 1;
        }
        let mut rf = 0usize;
        b.measure(name, || {
            let r = run_job(nf.as_ref(), m.clone(), &cfg).unwrap();
            t = r.report.total_s;
            rf = r.report.final_rf;
        });
        b.record(&format!("{name}_total"), t, "s");
        b.record(&format!("{name}_final_rf"), rf as f64, "nodes");
    }

    // --- work stealing on/off -------------------------------------------
    for (steal, name) in [(true, "steal_on"), (false, "steal_off")] {
        let cfg = JobConfig {
            sizing: TaskSizing::Tiniest,
            workers: 4,
            sched: SchedConfig { steal, ..Default::default() },
            ..Default::default()
        };
        let mut steals = 0u64;
        b.measure(name, || {
            let r = run_job(ds.as_ref(), m.clone(), &cfg).unwrap();
            t = r.report.total_s;
            steals = r.sched.steals;
        });
        b.record(&format!("{name}_total"), t, "s");
        b.record(&format!("{name}_steals"), steals as f64, "count");
    }

    b.finish();
}
