//! Fig 4 bench: REAL jobs through the full platform (PJRT execution,
//! dfs, scheduler) under the three sizing policies, with and without
//! outlier samples. The paper's ratios come from cache effects its
//! testbed had; here the measured deltas isolate the *platform* cost of
//! each sizing (scheduling + launch + padding), which is the half of the
//! tradeoff BTS has to keep small.

use std::sync::Arc;

use bts::coordinator::{run_job, JobConfig};
use bts::data::eaglet::{EagletConfig, EagletDataset};
use bts::data::Dataset;
use bts::kneepoint::TaskSizing;
use bts::runtime::Manifest;
use bts::util::bench::Bench;

fn main() {
    let Ok(m) = Manifest::load("artifacts") else {
        eprintln!("skipping fig4 bench: run `make artifacts`");
        return;
    };
    let m = Arc::new(m);
    let mut b = Bench::new("fig4_kneepoint").with_iters(1, 3);
    let full = EagletDataset::generate(
        &m.params,
        EagletConfig { families: 150, ..Default::default() },
    );
    let no_outliers = full.without_outliers();
    for (ds, tag) in [(&full, "outliers"), (&no_outliers, "clean")] {
        let mb = ds.total_bytes() as f64 / (1024.0 * 1024.0);
        for (sizing, name) in [
            (TaskSizing::Kneepoint(256 * 1024), "kneepoint"),
            (TaskSizing::Fixed(24 * 1024 * 1024), "large24MB"),
            (TaskSizing::Tiniest, "tiniest"),
        ] {
            let cfg = JobConfig { sizing, workers: 4, ..Default::default() };
            let mut last = 0.0;
            b.measure(&format!("{tag}_{name}"), || {
                let r = run_job(ds, m.clone(), &cfg).unwrap();
                last = r.report.total_s;
            });
            b.record(
                &format!("{tag}_{name}_tput"),
                mb / last,
                "MB/s",
            );
        }
    }
    b.finish();
}
