//! Fig 8 bench: BTS/BLT/BTT on both REAL workloads through the engine
//! (kneepoint sizes from the offline profiler), reporting throughput.

use std::sync::Arc;

use bts::cachesim::CacheConfig;
use bts::coordinator::{run_job, JobConfig};
use bts::data::Workload;
use bts::kneepoint::{kneepoint_bytes, TaskSizing};
use bts::runtime::Manifest;
use bts::util::bench::Bench;
use bts::workloads::build_small;

fn main() {
    let Ok(m) = Manifest::load("artifacts") else {
        eprintln!("skipping fig8 bench: run `make artifacts`");
        return;
    };
    let m = Arc::new(m);
    let mut b = Bench::new("fig8_task_sizing").with_iters(1, 3);
    let cache = CacheConfig::sandy_bridge();
    for (w, n_samples) in [
        (Workload::Eaglet, 120usize),
        (Workload::NetflixHi, 400),
        (Workload::NetflixLo, 400),
    ] {
        let ds = build_small(w, &m.params, n_samples);
        let knee = kneepoint_bytes(w, &cache);
        let mb = ds.total_bytes() as f64 / (1024.0 * 1024.0);
        for (sizing, name) in [
            (TaskSizing::Kneepoint(knee), "bts"),
            (TaskSizing::LargeSn { workers: 4 }, "blt"),
            (TaskSizing::Tiniest, "btt"),
        ] {
            let cfg = JobConfig { sizing, workers: 4, ..Default::default() };
            let mut total = 0.0;
            b.measure(&format!("{}_{name}", w.name()), || {
                total = run_job(ds.as_ref(), m.clone(), &cfg)
                    .unwrap()
                    .report
                    .total_s;
            });
            b.record(&format!("{}_{name}_tput", w.name()), mb / total, "MB/s");
        }
    }
    b.finish();
}
