//! Straggler mitigation: response-time-aware dynamic scheduling plus
//! speculative re-execution versus the plain two-step scheduler, under
//! a deterministically injected slow worker.
//!
//!     cargo bench --bench straggler_mitigation
//!
//! One map slot runs ~10x slower than its peers via
//! [`bts::util::testutil::Turbulence`] — slowness imposed *outside*
//! the worker's own timers, the way node contention really presents.
//! Two-step alone keeps the slot's dispatch window full and the job's
//! tail stretches to everything stranded there (the eclipse effect the
//! thesis warns tiny tasks about). Dynamic mode shrinks the slot's
//! window from the leader-observed response times and clones its
//! overdue tasks to idle fast slots; the first bit-identical result
//! wins. The headline comparison — p99 task turnaround and job wall
//! time, twostep vs dynamic+speculate — lands in
//! `results/BENCH_straggler.json`, and the run asserts the ≥2x tail
//! improvement the scheduler exists to deliver.

use std::sync::Arc;
use std::time::Duration;

use bts::data::{ModelParams, Workload};
use bts::exec::{run_cluster, Backend, ExecConfig, ExecResult};
use bts::kneepoint::TaskSizing;
use bts::scheduler::SchedConfig;
use bts::util::bench::Bench;
use bts::util::json::{num, obj, s, Json};
use bts::util::testutil::Turbulence;
use bts::workloads::build_small;

const WORKERS: usize = 4;
const SLOW_WORKER: usize = 3;
const SLOW_DELAY: Duration = Duration::from_millis(40);
const SAMPLES: usize = 240;
const SEED: u64 = 0xB75;
const ITERS: usize = 3;

fn run(backend: &Arc<Backend>, speculate: bool) -> ExecResult {
    let params = ModelParams::default();
    let ds = build_small(Workload::Eaglet, &params, SAMPLES);
    let cfg = ExecConfig {
        sizing: TaskSizing::Tiniest,
        workers: WORKERS,
        seed: SEED,
        sched: SchedConfig {
            dynamic: speculate,
            speculate,
            straggler_pct: 95.0,
            ..Default::default()
        },
        turbulence: Some(Arc::new(
            Turbulence::new(SEED).slow_from(SLOW_WORKER, 0, SLOW_DELAY),
        )),
        ..Default::default()
    };
    run_cluster(ds.as_ref(), backend.clone(), &cfg).expect("cluster run")
}

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

fn record(mode: &str, r: &ExecResult) -> Json {
    obj(vec![
        ("label", s(mode)),
        ("tasks", num(r.report.tasks as f64)),
        ("map_s", num(r.report.map_s)),
        ("total_s", num(r.report.total_s)),
        ("turnaround_p50_s", num(r.report.task_turnaround.p50)),
        ("turnaround_p99_s", num(r.report.task_turnaround.p99)),
        ("speculated", num(r.sched.speculated as f64)),
        ("won_by_clone", num(r.sched.won_by_clone as f64)),
    ])
}

fn main() {
    let backend = Arc::new(Backend::native(ModelParams::default()));
    let mut b = Bench::new("straggler_mitigation");

    let mut records = Vec::new();
    let mut base_p99 = Vec::new();
    let mut base_wall = Vec::new();
    let mut dyn_p99 = Vec::new();
    let mut dyn_wall = Vec::new();
    let mut outputs = Vec::new();

    for i in 0..ITERS {
        let base = run(&backend, false);
        let dynm = run(&backend, true);
        assert_eq!(
            base.output, dynm.output,
            "speculation changed the statistic"
        );
        base_p99.push(base.report.task_turnaround.p99);
        base_wall.push(base.report.map_s);
        dyn_p99.push(dynm.report.task_turnaround.p99);
        dyn_wall.push(dynm.report.map_s);
        assert!(
            dynm.sched.speculated >= 1,
            "the injected straggler was never speculated"
        );
        if i == 0 {
            records.push(record("twostep", &base));
            records.push(record("dynamic_speculate", &dynm));
        }
        outputs.push(dynm.output);
    }
    assert!(
        outputs.windows(2).all(|w| w[0] == w[1]),
        "speculative runs must be deterministic across repeats"
    );

    let base_p99 = median(base_p99);
    let dyn_p99 = median(dyn_p99);
    let base_wall = median(base_wall);
    let dyn_wall = median(dyn_wall);
    let p99_ratio = base_p99 / dyn_p99.max(1e-9);
    let wall_ratio = base_wall / dyn_wall.max(1e-9);
    b.record("twostep_p99_turnaround", base_p99, "s");
    b.record("dynamic_p99_turnaround", dyn_p99, "s");
    b.record("twostep_job_wall", base_wall, "s");
    b.record("dynamic_job_wall", dyn_wall, "s");
    b.record("p99_tail_ratio", p99_ratio, "x");
    b.record("job_wall_ratio", wall_ratio, "x");
    records.push(obj(vec![
        ("label", s("ratio")),
        ("p99_tail_ratio", num(p99_ratio)),
        ("job_wall_ratio", num(wall_ratio)),
    ]));

    let path = bts::util::bench_record::write("straggler", records)
        .expect("write BENCH_straggler.json");
    println!("wrote {path}");
    b.finish();

    // The acceptance bar: with a ~10x slow slot, dynamic + speculation
    // must cut the p99 task tail by at least 2x vs two-step alone (and
    // the job wall should move the same direction).
    assert!(
        p99_ratio >= 2.0,
        "p99 tail improved only {p99_ratio:.2}x (twostep {:.1}ms vs \
         dynamic {:.1}ms)",
        base_p99 * 1e3,
        dyn_p99 * 1e3,
    );
    assert!(
        wall_ratio >= 1.2,
        "job wall improved only {wall_ratio:.2}x"
    );
}
