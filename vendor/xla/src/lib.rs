//! API-compatible **stub** for the `xla` crate (PJRT bindings).
//!
//! The BTS runtime (`rust/src/runtime/client.rs`) executes AOT-lowered
//! HLO artifacts through PJRT. That path needs the native XLA runtime
//! library, which offline build hosts do not carry — so this crate
//! mirrors exactly the slice of the `xla` API the runtime uses and
//! fails *at runtime construction* (`PjRtClient::cpu`) with a clear
//! message instead of failing the build.
//!
//! The gate is deliberate and total: every fallible entry point returns
//! [`Error`], so a `Runtime` can never be constructed against the stub
//! and no artifact execution is silently wrong. Hosts with the real XLA
//! runtime swap this path dependency for the real `xla` crate in the
//! workspace manifest; nothing else in the tree changes.
//!
//! Jobs still run end to end without PJRT: the `bts::exec` subsystem
//! provides a pure-rust kernel backend (`exec::NativeExec`) that
//! computes the same map/reduce statistics natively.

use std::fmt;

/// Error type mirroring `xla::Error` (message-only in the stub).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: built against the vendored xla stub (no PJRT/XLA \
         runtime on this host); swap vendor/xla for the real xla crate \
         to execute compiled artifacts, or use the native exec backend"
    ))
}

/// Host literal storage. The stub keeps real data so the host-side
/// conversions (`vec1`/`reshape`/`to_vec`) behave faithfully; only
/// device execution is gated.
#[derive(Debug, Clone, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }
}

/// Element types a [`Literal`] can hold (f32 and i32 — the only dtypes
/// the BTS artifact contract uses).
pub trait NativeType: Copy + Sized {
    #[doc(hidden)]
    fn literal(v: &[Self]) -> Literal;
    #[doc(hidden)]
    fn extract(l: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn literal(v: &[Self]) -> Literal {
        Literal { data: Data::F32(v.to_vec()), dims: vec![v.len() as i64] }
    }

    fn extract(l: &Literal) -> Result<Vec<Self>> {
        match &l.data {
            Data::F32(v) => Ok(v.clone()),
            _ => Err(Error("Literal::to_vec: dtype mismatch (want f32)".into())),
        }
    }
}

impl NativeType for i32 {
    fn literal(v: &[Self]) -> Literal {
        Literal { data: Data::I32(v.to_vec()), dims: vec![v.len() as i64] }
    }

    fn extract(l: &Literal) -> Result<Vec<Self>> {
        match &l.data {
            Data::I32(v) => Ok(v.clone()),
            _ => Err(Error("Literal::to_vec: dtype mismatch (want i32)".into())),
        }
    }
}

/// A host-side tensor literal.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        T::literal(v)
    }

    /// Reshape without copying semantics; element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.data.len() {
            return Err(Error(format!(
                "Literal::reshape: {} elements into shape {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Destructure a tuple literal. The stub never produces tuples
    /// (execution is gated), so this always errors.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    /// Copy out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(self)
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// PJRT CPU client. Construction fails in the stub — this is the gate
/// that keeps every downstream execution path honest.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module (text interchange format).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(unavailable(&format!("HloModuleProto::from_text_file({path})")))
    }
}

/// An XLA computation wrapping a parsed module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_p: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn runtime_paths_are_gated() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("stub"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
