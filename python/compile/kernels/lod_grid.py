"""L1 Pallas kernel: ALOD-style grid statistic for EAGLET map tasks.

The compute hot-spot of the EAGLET workload: for a block of B family
chunks, score every subsampled marker and spread the scores onto a common
LOD grid with a tricube position weight.  The grid reduction is expressed
as a score x weight contraction so the non-interpret (TPU) lowering lands
on the MXU; the per-program working set  (bB*S*I + bB*S*G + bB*G) * 4 B
is a few KB — far under VMEM — so the BlockSpec tiles only the batch
dimension (see DESIGN.md §3 Hardware adaptation).

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, and the AOT HLO must execute on the rust CPU client.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import shapes

# Batch tile: one program instance handles BLOCK_B chunks.  Chosen so the
# tile divides every compiled bucket (1, 4, 16, 64).
BLOCK_B = 4


def _lod_grid_kernel(geno_ref, pos_ref, grid_ref, out_ref):
    geno = geno_ref[...]                               # [bB, S, I]
    pos = pos_ref[...]                                 # [bB, S]
    grid = grid_ref[...]                               # [G]

    # Per-marker linkage score: information-like m^2 / (var + eps).
    # Centered variance — the naive E[x^2]-m^2 form cancels catastrophically
    # for low-variance markers and diverges from the oracle.
    m = jnp.mean(geno, axis=-1)                        # [bB, S]
    d = geno - m[..., None]
    v = jnp.mean(d * d, axis=-1)
    score = (m * m) / (v + shapes.SCORE_EPS)

    # Tricube weights of each marker onto each grid point.
    u = jnp.abs(pos[:, :, None] - grid[None, None, :]) / shapes.BANDWIDTH
    w = jnp.where(u < 1.0, (1.0 - u**3) ** 3, 0.0)     # [bB, S, G]

    # Weighted average onto the grid (contraction over S -> MXU-shaped).
    num = jnp.einsum(
        "bs,bsg->bg", score, w, preferred_element_type=jnp.float32
    )
    den = jnp.sum(w, axis=1) + shapes.WEIGHT_EPS
    out_ref[...] = num / den


@functools.partial(jax.jit, static_argnames=())
def lod_grid(geno, pos, grid):
    """Pallas entry point; same contract as ref.lod_grid_ref.

    geno [B,S,I] f32, pos [B,S] f32, grid [G] f32 -> [B,G] f32.
    B must be a multiple of BLOCK_B (or < BLOCK_B, handled by a 1-wide tile).
    """
    b, s, i = geno.shape
    (g,) = grid.shape
    blk = BLOCK_B if b % BLOCK_B == 0 else 1
    return pl.pallas_call(
        _lod_grid_kernel,
        grid=(b // blk,),
        in_specs=[
            pl.BlockSpec((blk, s, i), lambda n: (n, 0, 0)),
            pl.BlockSpec((blk, s), lambda n: (n, 0)),
            pl.BlockSpec((g,), lambda n: (0,)),
        ],
        out_specs=pl.BlockSpec((blk, g), lambda n: (n, 0)),
        out_shape=jax.ShapeDtypeStruct((b, g), jnp.float32),
        interpret=True,
    )(geno, pos, grid)
