"""L1 Pallas kernel: per-month rating accumulators for Netflix map tasks.

For a block of B movie samples with S subsampled ratings each, accumulate
(sum, sumsq, count) per calendar month.  The month scatter is expressed as
a one-hot contraction ([B,S] x [B,S,12]) so the TPU lowering is a batched
matmul rather than a serial scatter; working set per program is
(3*bB*S + bB*S*12) * 4 B — trivially VMEM-resident.

interpret=True for CPU PJRT execution (see lod_grid.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import shapes

BLOCK_B = 4  # must divide every bucket in shapes.BUCKETS (or fall back to 1)


def _rating_stats_kernel(vals_ref, months_ref, mask_ref, out_ref):
    vals = vals_ref[...]                               # [bB, S]
    months = months_ref[...]                           # [bB, S]
    mask = mask_ref[...]                               # [bB, S]

    mo = jax.lax.broadcasted_iota(jnp.float32, (shapes.MONTHS,), 0)
    onehot = jnp.where(
        jnp.abs(months[:, :, None] - mo[None, None, :]) < 0.5, 1.0, 0.0
    ) * mask[:, :, None]                               # [bB, S, 12]

    s = jnp.einsum(
        "bs,bsm->bm", vals, onehot, preferred_element_type=jnp.float32
    )
    ss = jnp.einsum(
        "bs,bsm->bm", vals * vals, onehot, preferred_element_type=jnp.float32
    )
    c = jnp.sum(onehot, axis=1)
    out_ref[...] = jnp.stack([s, ss, c], axis=-1)      # [bB, 12, 3]


@functools.partial(jax.jit, static_argnames=())
def rating_stats(vals, months, mask):
    """Pallas entry point; same contract as ref.rating_stats_ref.

    vals/months/mask [B,S] f32 -> [B, 12, 3] f32 (sum, sumsq, count).
    """
    b, s = vals.shape
    blk = BLOCK_B if b % BLOCK_B == 0 else 1
    return pl.pallas_call(
        _rating_stats_kernel,
        grid=(b // blk,),
        in_specs=[
            pl.BlockSpec((blk, s), lambda n: (n, 0)),
            pl.BlockSpec((blk, s), lambda n: (n, 0)),
            pl.BlockSpec((blk, s), lambda n: (n, 0)),
        ],
        out_specs=pl.BlockSpec(
            (blk, shapes.MONTHS, shapes.STAT_FIELDS), lambda n: (n, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(
            (b, shapes.MONTHS, shapes.STAT_FIELDS), jnp.float32
        ),
        interpret=True,
    )(vals, months, mask)
