"""L1: Pallas kernels for the paper's compute hot-spots.

- lod_grid:      EAGLET ALOD grid statistic (subsampled-marker scoring)
- rating_stats:  Netflix per-month rating accumulators
- ref:           pure-jnp oracles for both (the pytest ground truth)
"""

from .lod_grid import lod_grid
from .rating_stats import rating_stats

__all__ = ["lod_grid", "rating_stats"]
