"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: `pytest python/tests` sweeps the
Pallas kernels (interpret=True) against these with hypothesis-generated
shapes/seeds and `assert_allclose`.  They are also what the L2 model would
compute if L1 were absent, so they double as the "pure-jnp reference
roofline" for the §Perf comparison.
"""

import jax.numpy as jnp

from .. import shapes


def lod_grid_ref(geno, pos, grid):
    """ALOD-style grid statistic over one subsample round.

    geno: [B, S, I] f32 genotype scores of the subsampled markers
    pos:  [B, S]    f32 genomic positions in [0, 1)
    grid: [G]       f32 common grid positions
    returns [B, G] f32: tricube position-weighted average of the per-marker
    linkage score  m^2 / (v + eps)  (information-like statistic).
    """
    m = jnp.mean(geno, axis=-1)                       # [B, S]
    d = geno - m[..., None]
    v = jnp.mean(d * d, axis=-1)                      # [B, S] centered (stable)
    score = (m * m) / (v + shapes.SCORE_EPS)          # [B, S]
    u = jnp.abs(pos[:, :, None] - grid[None, None, :]) / shapes.BANDWIDTH
    w = jnp.where(u < 1.0, (1.0 - u**3) ** 3, 0.0)    # [B, S, G] tricube
    num = jnp.einsum("bs,bsg->bg", score, w)
    den = jnp.sum(w, axis=1) + shapes.WEIGHT_EPS
    return num / den


def rating_stats_ref(vals, months, mask):
    """Per-month rating accumulators over one subsampled batch.

    vals:   [B, S] f32 rating values
    months: [B, S] f32 month index in [0, 12) (integral values)
    mask:   [B, S] f32 1.0 = valid rating, 0.0 = padding
    returns [B, 12, 3] f32: (sum, sumsq, count) per month.
    """
    mo = jnp.arange(shapes.MONTHS, dtype=vals.dtype)
    onehot = jnp.where(
        jnp.abs(months[:, :, None] - mo[None, None, :]) < 0.5, 1.0, 0.0
    ) * mask[:, :, None]                              # [B, S, 12]
    s = jnp.einsum("bs,bsm->bm", vals, onehot)
    ss = jnp.einsum("bs,bsm->bm", vals * vals, onehot)
    c = jnp.sum(onehot, axis=1)
    return jnp.stack([s, ss, c], axis=-1)             # [B, 12, 3]
