"""AOT: lower every L2 entry point to HLO *text* + a manifest.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (one per entry point x bucket):
    artifacts/<name>.hlo.txt
    artifacts/manifest.json   — shapes, dtypes, buckets, model params

The Makefile makes this a no-op when inputs are unchanged; additionally we
skip rewrites when content is identical so artifact mtimes stay stable.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, shapes

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def entry_points():
    """Yield (name, kind, bucket, fn, arg_specs, input_names, output_names)."""
    s = shapes
    for b in s.BUCKETS:
        yield (
            f"eaglet_map_b{b}",
            "eaglet_map",
            b,
            model.eaglet_map,
            [
                spec((b, s.MARKERS, s.INDIVIDUALS)),
                spec((b, s.MARKERS)),
                spec((s.ROUNDS, s.SUBSAMPLE), I32),
                spec((s.GRID,)),
            ],
            ["geno", "pos", "idx", "grid"],
            ["alod"],
        )
        for conf, sub in (("hi", s.S_HI), ("lo", s.S_LO)):
            yield (
                f"netflix_map_{conf}_b{b}",
                f"netflix_map_{conf}",
                b,
                model.netflix_map,
                [
                    spec((b, s.RATINGS_CAP)),
                    spec((b, s.RATINGS_CAP)),
                    spec((b, s.RATINGS_CAP)),
                    spec((sub,), I32),
                ],
                ["vals", "months", "mask", "idx"],
                ["stats"],
            )
    yield (
        "eaglet_reduce",
        "eaglet_reduce",
        s.REDUCE_FAN,
        model.eaglet_reduce,
        [spec((s.REDUCE_FAN, s.GRID)), spec((s.REDUCE_FAN,))],
        ["parts", "weights"],
        ["wsum", "wtot"],
    )
    yield (
        "netflix_reduce",
        "netflix_reduce",
        s.REDUCE_FAN,
        model.netflix_reduce,
        [spec((s.REDUCE_FAN, s.MONTHS, s.STAT_FIELDS))],
        ["parts"],
        ["stats"],
    )


def params_block():
    s = shapes
    return {
        "markers": s.MARKERS,
        "individuals": s.INDIVIDUALS,
        "subsample": s.SUBSAMPLE,
        "rounds": s.ROUNDS,
        "grid": s.GRID,
        "bandwidth": s.BANDWIDTH,
        "ratings_cap": s.RATINGS_CAP,
        "months": s.MONTHS,
        "s_hi": s.S_HI,
        "s_lo": s.S_LO,
        "stat_fields": s.STAT_FIELDS,
        "buckets": list(s.BUCKETS),
        "reduce_fan": s.REDUCE_FAN,
        "chunk_bytes": s.CHUNK_BYTES,
    }


def write_if_changed(path: str, text: str) -> bool:
    if os.path.exists(path):
        with open(path) as f:
            if f.read() == text:
                return False
    with open(path, "w") as f:
        f.write(text)
    return True


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter on names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"version": 1, "params": params_block(), "entries": []}
    for name, kind, bucket, fn, arg_specs, in_names, out_names in entry_points():
        if args.only and args.only not in name:
            continue
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        changed = write_if_changed(os.path.join(args.out_dir, fname), text)
        out_shapes = [
            {"shape": list(o.shape), "dtype": str(o.dtype)}
            for o in lowered.out_info
        ]
        manifest["entries"].append(
            {
                "name": name,
                "kind": kind,
                "bucket": bucket,
                "file": fname,
                "inputs": [
                    {
                        "name": n,
                        "shape": list(a.shape),
                        "dtype": str(a.dtype),
                    }
                    for n, a in zip(in_names, arg_specs)
                ],
                "outputs": [
                    {"name": n, **o} for n, o in zip(out_names, out_shapes)
                ],
            }
        )
        print(f"{'wrote' if changed else 'kept '} {fname} ({len(text)} chars)")

    write_if_changed(
        os.path.join(args.out_dir, "manifest.json"),
        json.dumps(manifest, indent=2) + "\n",
    )
    print(f"manifest: {len(manifest['entries'])} entries")


if __name__ == "__main__":
    main()
