"""Canonical shapes shared by L1 kernels, L2 models, AOT lowering and tests.

These are the *compiled* (bucketed) shapes: HLO artifacts are shape-static,
so the rust runtime pads each task to the next bucket and masks via reduce
weights.  The same constants are exported into artifacts/manifest.json so
the rust side never hardcodes them.

EAGLET data model (synthetic stand-in for family SNP linkage data, see
DESIGN.md §2): a family is one or more fixed-size *chunks* of
[MARKERS x INDIVIDUALS] genotype scores plus per-marker genomic positions
in [0, 1).  Outlier families simply span many chunks (the paper: one 15x
and one 7x sample).  A map task is a batch of B chunks; each subsample
round picks SUBSAMPLE of the MARKERS, and the ALOD statistic is averaged
over ROUNDS rounds on a common GRID.

Netflix data model: a movie sample is up to RATINGS_CAP rating tuples
(value, month, valid-mask); a map task subsamples S_HI (high-confidence)
or S_LO (low-confidence) ratings per movie and accumulates per-month
(sum, sumsq, count).
"""

# --- EAGLET -----------------------------------------------------------------
MARKERS = 64          # M: SNP markers per chunk
INDIVIDUALS = 8       # I: individuals per chunk
SUBSAMPLE = 16        # S: markers drawn per subsample round
ROUNDS = 8            # R: subsample rounds averaged into the ALOD
GRID = 32             # G: common LOD grid positions
BANDWIDTH = 0.15      # tricube kernel bandwidth on [0,1) positions
SCORE_EPS = 1e-3      # variance floor in the per-marker linkage score
WEIGHT_EPS = 1e-6     # denominator floor in the grid-weighted average

# --- Netflix ----------------------------------------------------------------
RATINGS_CAP = 256     # N: padded ratings per movie sample
MONTHS = 12
S_HI = 128            # high-confidence subsample size
S_LO = 16             # low-confidence subsample size
STAT_FIELDS = 3       # (sum, sumsq, count)

# --- Bucketing / reduce ------------------------------------------------------
BUCKETS = (1, 4, 16, 64)   # samples(-chunks) per compiled map task
REDUCE_FAN = 16            # K: parts combined per reduce artifact call

# Bytes per EAGLET chunk as stored in the data layer (geno f32 + pos f32).
CHUNK_BYTES = MARKERS * INDIVIDUALS * 4 + MARKERS * 4


def bucket_for(n: int) -> int:
    """Smallest compiled bucket >= n (callers split tasks larger than max)."""
    for b in BUCKETS:
        if n <= b:
            return b
    raise ValueError(f"task of {n} chunks exceeds largest bucket {BUCKETS[-1]}")
