"""L2: jax map/reduce compute graphs for both subsampling workloads.

Each function here is an AOT entry point (lowered by aot.py at the bucket
shapes in shapes.py).  The subsample *gather* lives at this layer —
subsampling decides its indices at runtime, so the L3 coordinator ships the
round indices with every task — while the dense hot-spot is delegated to
the L1 Pallas kernels so both lower into one HLO module.

All entry points return tuples (lowered with return_tuple=True; the rust
side unwraps with to_tupleN).
"""

import jax.numpy as jnp
from jax import lax

from . import shapes
from .kernels import lod_grid, rating_stats


# --- EAGLET ------------------------------------------------------------------

def eaglet_map(geno, pos, idx, grid):
    """One map task: ALOD over ROUNDS subsample rounds for B family chunks.

    geno: [B, M, I] f32   genotype scores for all markers of each chunk
    pos:  [B, M]    f32   genomic positions of all markers
    idx:  [R, S]    i32   subsample-round marker indices (chosen by L3)
    grid: [G]       f32   common LOD grid
    returns ([B, G] f32,) — per-chunk ALOD (mean LOD over rounds).
    """

    def one_round(ix):
        g = jnp.take(geno, ix, axis=1)    # [B, S, I]
        p = jnp.take(pos, ix, axis=1)     # [B, S]
        return lod_grid(g, p, grid)       # [B, G]

    lods = lax.map(one_round, idx)        # [R, B, G]
    return (jnp.mean(lods, axis=0),)


def eaglet_reduce(parts, weights):
    """Associative combine of K per-task ALOD grids.

    parts:   [K, G] f32 partial ALODs (zero-padded rows allowed)
    weights: [K]    f32 chunk weights (0.0 for padding)
    returns ([G] f32 weighted sum, [1] f32 weight total) — the final
    division happens after the L3 reduce tree bottoms out.
    """
    wsum = jnp.einsum("kg,k->g", parts, weights)
    wtot = jnp.sum(weights)[None]
    return (wsum, wtot)


# --- Netflix -----------------------------------------------------------------

def netflix_map(vals, months, mask, idx):
    """One map task: per-month stats over a subsample of each movie's ratings.

    vals/months/mask: [B, N] f32 padded rating tuples for B movies
    idx:              [S]    i32 subsample positions (shared across movies;
                      L3 draws fresh indices per task)
    returns ([B, 12, 3] f32,) — per-movie (sum, sumsq, count) by month.
    """
    v = jnp.take(vals, idx, axis=1)       # [B, S]
    m = jnp.take(months, idx, axis=1)
    k = jnp.take(mask, idx, axis=1)
    return (rating_stats(v, m, k),)


def netflix_reduce(parts):
    """Associative combine of K per-task stat tensors.

    parts: [K, 12, 3] f32 -> ([12, 3] f32,).  Sums are associative, so the
    L3 reduce tree applies this repeatedly; mean/CI finalization is scalar
    math done by the reporter.
    """
    return (jnp.sum(parts, axis=0),)


# --- Pure-jnp references for whole entry points (used by tests) ---------------

def eaglet_map_ref(geno, pos, idx, grid):
    from .kernels import ref

    def one_round(ix):
        g = jnp.take(geno, ix, axis=1)
        p = jnp.take(pos, ix, axis=1)
        return ref.lod_grid_ref(g, p, grid)

    lods = lax.map(one_round, idx)
    return (jnp.mean(lods, axis=0),)


def netflix_map_ref(vals, months, mask, idx):
    from .kernels import ref

    v = jnp.take(vals, idx, axis=1)
    m = jnp.take(months, idx, axis=1)
    k = jnp.take(mask, idx, axis=1)
    return (ref.rating_stats_ref(v, m, k),)
