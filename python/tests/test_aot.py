"""AOT pipeline tests: manifest coherence and HLO-text executability.

The executability test round-trips one lowered module through the same
XLA client the rust runtime uses (compile HLO text, execute, compare to
direct jax execution) — if this passes, the rust loader sees valid input.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model, shapes

jax.config.update("jax_platform_name", "cpu")

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_entry_point_inventory():
    entries = list(aot.entry_points())
    names = [e[0] for e in entries]
    assert len(names) == len(set(names))
    # 3 map kinds x len(BUCKETS) + 2 reduces
    assert len(names) == 3 * len(shapes.BUCKETS) + 2
    for b in shapes.BUCKETS:
        assert f"eaglet_map_b{b}" in names
        assert f"netflix_map_hi_b{b}" in names
        assert f"netflix_map_lo_b{b}" in names


def test_params_block_matches_shapes():
    p = aot.params_block()
    assert p["markers"] == shapes.MARKERS
    assert p["buckets"] == list(shapes.BUCKETS)
    assert p["chunk_bytes"] == shapes.CHUNK_BYTES


def test_bucket_for():
    assert shapes.bucket_for(1) == 1
    assert shapes.bucket_for(2) == 4
    assert shapes.bucket_for(16) == 16
    assert shapes.bucket_for(17) == 64
    with pytest.raises(ValueError):
        shapes.bucket_for(65)


def test_hlo_text_is_stable_and_well_formed():
    """Lower netflix_map at b=1 and sanity-check the HLO text interchange.

    Actual *execution* of the text artifacts is covered by the rust
    integration tests (rust/tests/runtime_roundtrip.rs), which load the
    same files through the PJRT CPU client used at request time.
    """
    s = shapes
    arg_specs = [
        jax.ShapeDtypeStruct((1, s.RATINGS_CAP), jnp.float32),
        jax.ShapeDtypeStruct((1, s.RATINGS_CAP), jnp.float32),
        jax.ShapeDtypeStruct((1, s.RATINGS_CAP), jnp.float32),
        jax.ShapeDtypeStruct((s.S_LO,), jnp.int32),
    ]
    lowered = jax.jit(model.netflix_map).lower(*arg_specs)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # 4 parameters, f32/s32 only, output is a 1-tuple of [1,12,3].
    assert text.count("parameter(") >= 4
    assert f"f32[1,{s.MONTHS},{s.STAT_FIELDS}]" in text
    # Deterministic: lowering twice yields byte-identical text (this is
    # what lets aot.py skip rewrites and keep artifact mtimes stable).
    text2 = aot.to_hlo_text(jax.jit(model.netflix_map).lower(*arg_specs))
    assert text2 == text


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestBuiltArtifacts:
    def test_manifest_files_exist_and_parse(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            man = json.load(f)
        assert man["version"] == 1
        assert len(man["entries"]) == 3 * len(shapes.BUCKETS) + 2
        for e in man["entries"]:
            path = os.path.join(ART, e["file"])
            assert os.path.exists(path), e["file"]
            with open(path) as f:
                head = f.read(64)
            assert "HloModule" in head
            assert e["bucket"] >= 1
            for t in e["inputs"] + e["outputs"]:
                assert t["dtype"] in ("float32", "int32")

    def test_manifest_shapes_match_entry_points(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            man = json.load(f)
        by_name = {e["name"]: e for e in man["entries"]}
        for name, _, bucket, _, arg_specs, in_names, _ in aot.entry_points():
            e = by_name[name]
            assert e["bucket"] == bucket
            assert [i["name"] for i in e["inputs"]] == in_names
            assert [tuple(i["shape"]) for i in e["inputs"]] == [
                a.shape for a in arg_specs
            ]
