"""L2 correctness: full map/reduce entry points vs pure-jnp references,
plus algebraic invariants the L3 reduce tree relies on (associativity,
padding-neutrality)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model, shapes

jax.config.update("jax_platform_name", "cpu")


def _eaglet_task(seed, b):
    k = jax.random.split(jax.random.PRNGKey(seed), 3)
    geno = jax.random.normal(
        k[0], (b, shapes.MARKERS, shapes.INDIVIDUALS), dtype=jnp.float32
    )
    pos = jnp.sort(
        jax.random.uniform(k[1], (b, shapes.MARKERS), dtype=jnp.float32),
        axis=1,
    )
    idx = jax.random.randint(
        k[2], (shapes.ROUNDS, shapes.SUBSAMPLE), 0, shapes.MARKERS
    ).astype(jnp.int32)
    grid = jnp.linspace(0.0, 1.0, shapes.GRID, dtype=jnp.float32)
    return geno, pos, idx, grid


def _netflix_task(seed, b, s):
    k = jax.random.split(jax.random.PRNGKey(seed), 4)
    vals = jax.random.uniform(k[0], (b, shapes.RATINGS_CAP)) * 4.0 + 1.0
    months = jnp.floor(jax.random.uniform(k[1], (b, shapes.RATINGS_CAP)) * 12)
    mask = (jax.random.uniform(k[2], (b, shapes.RATINGS_CAP)) > 0.3).astype(
        jnp.float32
    )
    idx = jax.random.randint(k[3], (s,), 0, shapes.RATINGS_CAP).astype(
        jnp.int32
    )
    return vals.astype(jnp.float32), months.astype(jnp.float32), mask, idx


class TestEagletMap:
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 1000), b=st.sampled_from([1, 4]))
    def test_matches_ref(self, seed, b):
        geno, pos, idx, grid = _eaglet_task(seed, b)
        (got,) = model.eaglet_map(geno, pos, idx, grid)
        (want,) = model.eaglet_map_ref(geno, pos, idx, grid)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_bucket_shapes(self):
        for b in shapes.BUCKETS[:3]:
            geno, pos, idx, grid = _eaglet_task(1, b)
            (alod,) = model.eaglet_map(geno, pos, idx, grid)
            assert alod.shape == (b, shapes.GRID)

    def test_alod_is_round_mean(self):
        geno, pos, idx, grid = _eaglet_task(5, 4)
        # one-round idx repeated R times == single-round result
        idx_rep = jnp.tile(idx[:1], (shapes.ROUNDS, 1))
        (alod,) = model.eaglet_map(geno, pos, idx_rep, grid)
        (one,) = model.eaglet_map(geno, pos, idx_rep[:1].repeat(shapes.ROUNDS, 0), grid)
        np.testing.assert_allclose(alod, one, rtol=1e-6)


class TestEagletReduce:
    def test_weighted_combine(self):
        parts = jnp.arange(
            shapes.REDUCE_FAN * shapes.GRID, dtype=jnp.float32
        ).reshape(shapes.REDUCE_FAN, shapes.GRID)
        w = jnp.ones((shapes.REDUCE_FAN,), dtype=jnp.float32)
        wsum, wtot = model.eaglet_reduce(parts, w)
        np.testing.assert_allclose(wsum, parts.sum(axis=0), rtol=1e-6)
        assert float(wtot[0]) == shapes.REDUCE_FAN

    def test_zero_weight_padding_is_neutral(self):
        k = jax.random.PRNGKey(0)
        parts = jax.random.normal(k, (shapes.REDUCE_FAN, shapes.GRID))
        w = jnp.zeros((shapes.REDUCE_FAN,)).at[:3].set(2.0)
        wsum, wtot = model.eaglet_reduce(parts, w)
        np.testing.assert_allclose(
            wsum, 2.0 * parts[:3].sum(axis=0), rtol=1e-5, atol=1e-5
        )
        assert float(wtot[0]) == 6.0

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 1000))
    def test_tree_associativity(self, seed):
        # combining in two levels equals one flat weighted sum
        k = jax.random.split(jax.random.PRNGKey(seed), 2)
        parts = jax.random.normal(k[0], (shapes.REDUCE_FAN, shapes.GRID))
        w = jax.random.uniform(k[1], (shapes.REDUCE_FAN,))
        wsum, wtot = model.eaglet_reduce(parts, w)
        # level 2: feed (wsum, wtot) back as a weighted part of itself
        parts2 = jnp.zeros_like(parts).at[0].set(wsum / wtot[0])
        w2 = jnp.zeros_like(w).at[0].set(wtot[0])
        wsum2, wtot2 = model.eaglet_reduce(parts2, w2)
        np.testing.assert_allclose(wsum2, wsum, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(wtot2, wtot, rtol=1e-6)


class TestNetflixMap:
    @settings(max_examples=8, deadline=None)
    @given(
        seed=st.integers(0, 1000),
        b=st.sampled_from([1, 4]),
        s=st.sampled_from([shapes.S_LO, shapes.S_HI]),
    )
    def test_matches_ref(self, seed, b, s):
        vals, months, mask, idx = _netflix_task(seed, b, s)
        (got,) = model.netflix_map(vals, months, mask, idx)
        (want,) = model.netflix_map_ref(vals, months, mask, idx)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_count_bounded_by_subsample(self):
        vals, months, mask, idx = _netflix_task(3, 4, shapes.S_LO)
        (stats,) = model.netflix_map(vals, months, mask, idx)
        counts = np.asarray(stats)[:, :, 2].sum(axis=1)
        assert (counts <= shapes.S_LO).all()


class TestNetflixReduce:
    def test_sum_combine(self):
        parts = jnp.ones(
            (shapes.REDUCE_FAN, shapes.MONTHS, shapes.STAT_FIELDS)
        )
        (out,) = model.netflix_reduce(parts)
        np.testing.assert_allclose(out, shapes.REDUCE_FAN)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 1000))
    def test_associative(self, seed):
        k = jax.random.PRNGKey(seed)
        parts = jax.random.normal(
            k, (shapes.REDUCE_FAN, shapes.MONTHS, shapes.STAT_FIELDS)
        )
        (whole,) = model.netflix_reduce(parts)
        (a,) = model.netflix_reduce(
            jnp.concatenate([parts[:8], jnp.zeros_like(parts[:8])])
        )
        (b,) = model.netflix_reduce(
            jnp.concatenate([parts[8:], jnp.zeros_like(parts[8:])])
        )
        np.testing.assert_allclose(a + b, whole, rtol=1e-5, atol=1e-5)
