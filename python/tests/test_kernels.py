"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and seeds; every case asserts allclose against
ref.py.  This is the CORE correctness signal for the compute layer — the
AOT artifacts embed exactly these kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import shapes
from compile.kernels import lod_grid, rating_stats, ref

jax.config.update("jax_platform_name", "cpu")

RTOL = 1e-4
ATOL = 1e-5


def _key(seed):
    return jax.random.PRNGKey(seed)


def _eaglet_inputs(seed, b, s, i, g):
    k1, k2 = jax.random.split(_key(seed))
    geno = jax.random.normal(k1, (b, s, i), dtype=jnp.float32)
    pos = jax.random.uniform(k2, (b, s), dtype=jnp.float32)
    grid = jnp.linspace(0.0, 1.0, g, dtype=jnp.float32)
    return geno, pos, grid


def _netflix_inputs(seed, b, s):
    k1, k2, k3 = jax.random.split(_key(seed), 3)
    vals = jax.random.uniform(k1, (b, s), dtype=jnp.float32) * 4.0 + 1.0
    months = jnp.floor(jax.random.uniform(k2, (b, s)) * shapes.MONTHS)
    mask = (jax.random.uniform(k3, (b, s)) > 0.25).astype(jnp.float32)
    return vals, months.astype(jnp.float32), mask


class TestLodGrid:
    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        b=st.sampled_from([1, 2, 4, 8, 16]),
        s=st.sampled_from([4, 16, 32]),
        i=st.sampled_from([2, 8]),
        g=st.sampled_from([8, 32]),
    )
    def test_matches_ref(self, seed, b, s, i, g):
        geno, pos, grid = _eaglet_inputs(seed, b, s, i, g)
        got = lod_grid(geno, pos, grid)
        want = ref.lod_grid_ref(geno, pos, grid)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    def test_canonical_shapes(self):
        geno, pos, grid = _eaglet_inputs(
            0, 4, shapes.SUBSAMPLE, shapes.INDIVIDUALS, shapes.GRID
        )
        out = lod_grid(geno, pos, grid)
        assert out.shape == (4, shapes.GRID)
        assert out.dtype == jnp.float32

    def test_constant_geno_zero_variance_is_finite(self):
        # m^2/(v+eps) must not blow up when every individual agrees.
        geno = jnp.ones((4, 8, 4), dtype=jnp.float32) * 2.0
        pos = jnp.linspace(0.1, 0.9, 8)[None, :].repeat(4, axis=0)
        grid = jnp.linspace(0.0, 1.0, 16, dtype=jnp.float32)
        out = lod_grid(geno, pos, grid)
        assert bool(jnp.all(jnp.isfinite(out)))
        want = ref.lod_grid_ref(geno, pos, grid)
        np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)

    def test_far_markers_contribute_nothing(self):
        # markers clustered at 0.0 leave grid points > bandwidth untouched.
        geno = jax.random.normal(_key(3), (1, 8, 4), dtype=jnp.float32)
        pos = jnp.zeros((1, 8), dtype=jnp.float32)
        grid = jnp.array([0.0, 0.9], dtype=jnp.float32)
        out = np.asarray(lod_grid(geno, pos, grid))
        assert abs(out[0, 1]) < 1e-4  # tricube support exceeded
        assert abs(out[0, 0]) > 0.0

    def test_batch_tiling_invariance(self):
        # B=8 (tiled BLOCK_B=4) must equal two stacked B=4 calls.
        geno, pos, grid = _eaglet_inputs(7, 8, 16, 4, 16)
        whole = lod_grid(geno, pos, grid)
        halves = jnp.concatenate(
            [
                lod_grid(geno[:4], pos[:4], grid),
                lod_grid(geno[4:], pos[4:], grid),
            ]
        )
        np.testing.assert_allclose(whole, halves, rtol=RTOL, atol=ATOL)


class TestRatingStats:
    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        b=st.sampled_from([1, 2, 4, 8, 16]),
        s=st.sampled_from([4, 16, 128]),
    )
    def test_matches_ref(self, seed, b, s):
        vals, months, mask = _netflix_inputs(seed, b, s)
        got = rating_stats(vals, months, mask)
        want = ref.rating_stats_ref(vals, months, mask)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    def test_counts_partition_the_mask(self):
        vals, months, mask = _netflix_inputs(11, 8, 64)
        out = np.asarray(rating_stats(vals, months, mask))
        np.testing.assert_allclose(
            out[:, :, 2].sum(axis=1), np.asarray(mask).sum(axis=1), rtol=1e-6
        )

    def test_masked_out_rows_are_zero(self):
        vals, months, _ = _netflix_inputs(13, 4, 32)
        out = np.asarray(rating_stats(vals, months, jnp.zeros_like(vals)))
        np.testing.assert_array_equal(out, np.zeros_like(out))

    def test_single_month_accumulates_all(self):
        b, s = 2, 16
        vals = jnp.ones((b, s), dtype=jnp.float32) * 3.0
        months = jnp.full((b, s), 5.0, dtype=jnp.float32)
        mask = jnp.ones((b, s), dtype=jnp.float32)
        out = np.asarray(rating_stats(vals, months, mask))
        np.testing.assert_allclose(out[:, 5, 0], 48.0)  # 16 * 3
        np.testing.assert_allclose(out[:, 5, 1], 144.0)  # 16 * 9
        np.testing.assert_allclose(out[:, 5, 2], 16.0)
        other = np.delete(out, 5, axis=1)
        np.testing.assert_array_equal(other, np.zeros_like(other))

    @pytest.mark.parametrize("b", [1, 4, 16])
    def test_bucket_shapes(self, b):
        vals, months, mask = _netflix_inputs(17, b, shapes.S_LO)
        out = rating_stats(vals, months, mask)
        assert out.shape == (b, shapes.MONTHS, shapes.STAT_FIELDS)
